//! Warm predictor registry — the reason the server is *resident*.
//!
//! Building a predictor is the expensive part of a short simulation job
//! (artifact resolution, weight loading, buffer allocation), so the
//! server builds each distinct [`JobRequest::predictor_key`] once and
//! keeps the live predictor warm across jobs. Subsequent jobs with the
//! same key — from any client — reuse the entry, and the per-worker
//! `fork` path inside the engine still applies on top (forked handles
//! share the warm weights).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::api::job::JobRequest;
use crate::predictor::LatencyPredictor;

/// A warm predictor shared between scheduler runs. The mutex serializes
/// groups on the same predictor; jobs *within* a group share batches
/// inside one engine instead of contending on this lock.
pub type SharedPredictor = Arc<Mutex<Box<dyn LatencyPredictor>>>;

struct Entry {
    predictor: SharedPredictor,
    label: String,
    jobs: u64,
}

/// One warm entry per distinct predictor key (see module docs).
#[derive(Default)]
pub struct PredictorRegistry {
    entries: Mutex<HashMap<String, Entry>>,
}

/// Usage counters for one registry entry (`repro status --stats` view).
#[derive(Debug, Clone)]
pub struct RegistryStat {
    /// The predictor key ([`JobRequest::predictor_key`]).
    pub key: String,
    /// Human-readable predictor label.
    pub label: String,
    /// Jobs that have acquired this entry.
    pub jobs: u64,
    /// Predictions served by the warm predictor so far.
    pub served: u64,
}

impl PredictorRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The warm predictor for `job`'s key, building it on first use.
    /// `group_jobs` is the number of jobs acquiring it together (one
    /// co-batched group counts every member).
    pub fn acquire(&self, job: &JobRequest, group_jobs: u64) -> Result<SharedPredictor> {
        let key = job.predictor_key();
        let mut entries = self.entries.lock().unwrap();
        if let Some(entry) = entries.get_mut(&key) {
            entry.jobs += group_jobs;
            return Ok(entry.predictor.clone());
        }
        let built = job
            .predictor
            .build()
            .with_context(|| format!("building predictor for key {key}"))?;
        let predictor: SharedPredictor = Arc::new(Mutex::new(built));
        entries.insert(
            key,
            Entry { predictor: predictor.clone(), label: job.predictor.label(), jobs: group_jobs },
        );
        Ok(predictor)
    }

    /// Usage counters for every warm entry, sorted by key for stable
    /// output.
    pub fn stats(&self) -> Vec<RegistryStat> {
        let entries = self.entries.lock().unwrap();
        let mut out: Vec<RegistryStat> = entries
            .iter()
            .map(|(key, e)| RegistryStat {
                key: key.clone(),
                label: e.label.clone(),
                jobs: e.jobs,
                served: e.predictor.lock().unwrap().served(),
            })
            .collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }

    /// Number of warm entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether no predictor has been built yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::JobSource;
    use crate::api::PredictorSpec;

    fn job(seq: usize) -> JobRequest {
        JobRequest::new(
            JobSource::Bench { name: "gcc".into(), n: 100 },
            PredictorSpec::table(seq),
        )
    }

    #[test]
    fn same_key_shares_one_entry() {
        let reg = PredictorRegistry::new();
        let a = reg.acquire(&job(8), 1).unwrap();
        let b = reg.acquire(&job(8), 2).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "equal keys must share the warm predictor");
        assert_eq!(reg.len(), 1);
        let c = reg.acquire(&job(16), 1).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.len(), 2);
        let stats = reg.stats();
        assert_eq!(stats[0].jobs, 3, "group acquisition counts every member");
        assert_eq!(stats[0].label, "table");
    }

    #[test]
    fn served_counts_accumulate_across_jobs() {
        let reg = PredictorRegistry::new();
        let p = reg.acquire(&job(8), 1).unwrap();
        {
            let mut p = p.lock().unwrap();
            let inputs = vec![0.0f32; p.seq_len() * crate::features::NUM_FEATURES];
            p.predict(&inputs, 1).unwrap();
        }
        assert_eq!(reg.stats()[0].served, 1);
    }

    #[test]
    fn bad_spec_is_a_named_build_error() {
        let reg = PredictorRegistry::new();
        let err = reg.acquire(&job(0), 1).unwrap_err().to_string();
        assert!(err.contains("table/seq=0"), "err: {err}");
        assert!(reg.is_empty(), "failed builds leave no entry behind");
    }
}
