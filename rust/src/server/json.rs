//! Minimal strict JSON for the job-server wire protocol (serde is not
//! vendored in this image, so the protocol layer parses by hand).
//!
//! [`Value::parse`] accepts exactly the JSON grammar — named errors with
//! byte offsets, a recursion-depth cap, no trailing garbage — and
//! [`Value::render`] emits a canonical single-line form (object keys in
//! their original order, integers rendered without a fraction). Parsing
//! and re-rendering a report therefore yields a stable canonical string,
//! which is what the daemon-vs-direct equivalence tests compare after
//! zeroing the timing fields.

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// Objects stay ordered (insertion order), so a parse → render round
/// trip of protocol messages is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object, in key insertion order (duplicate keys rejected).
    Obj(Vec<(String, Value)>),
}

/// Parser recursion cap — far above any protocol message, low enough
/// that a hostile deeply-nested line cannot blow the daemon's stack.
const MAX_DEPTH: usize = 64;

impl Value {
    /// Parse one complete JSON document; trailing non-whitespace is an
    /// error, as is any grammar violation (named, with a byte offset).
    pub fn parse(input: &str) -> Result<Value> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("json: trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Member lookup on an object (`None` for other variants or a
    /// missing key).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mutable member lookup on an object.
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        match self {
            Value::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Replace (or append) a member on an object; no-op on other
    /// variants.
    pub fn set(&mut self, key: &str, val: Value) {
        if let Value::Obj(pairs) = self {
            for (k, v) in pairs.iter_mut() {
                if k == key {
                    *v = val;
                    return;
                }
            }
            pairs.push((key.to_string(), val));
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer payload: a number with no fractional part
    /// inside the f64-exact range (`<= 2^53`).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// Boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Member slice, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Whether this is JSON `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Canonical single-line rendering (see module docs).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => out.push_str(&render_num(*x)),
            Value::Str(s) => out.push_str(&quote(s)),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&quote(k));
                    out.push_str(": ");
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Canonical number form: integers (within f64-exact range) render with
/// no fraction, so `1.500000` and `1.5` both survive a round trip as a
/// single stable spelling.
fn render_num(x: f64) -> String {
    if !x.is_finite() {
        return "null".into();
    }
    if x.fract() == 0.0 && x.abs() <= 9_007_199_254_740_992.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Strict-schema helper: reject object members outside `accepted`,
/// naming the offending field and the accepted list (the protocol's
/// "misspelled knob is a named error" rule).
pub fn check_keys(obj: &[(String, Value)], ctx: &str, accepted: &[&str]) -> Result<()> {
    for (k, _) in obj {
        if !accepted.contains(&k.as_str()) {
            bail!("{ctx}: unknown field \"{k}\"; accepted: {}", accepted.join(", "));
        }
    }
    Ok(())
}

/// Render a string as a JSON string literal.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> anyhow::Error {
        anyhow!("json: {msg} at byte {}", self.i)
    }

    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if matches!(c, b' ' | b'\t' | b'\n' | b'\r') {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, lit: &str) -> Result<()> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.eat("null").map(|_| Value::Null),
            Some(b't') => self.eat("true").map(|_| Value::Bool(true)),
            Some(b'f') => self.eat("false").map(|_| Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character `{}`", c as char))),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.i += 1; // consume '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.i += 1; // consume '{'
        let mut pairs: Vec<(String, Value)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected string key in object"));
            }
            let key = self.string()?;
            if pairs.iter().any(|(k, _)| *k == key) {
                return Err(self.err(&format!("duplicate key \"{key}\"")));
            }
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected `:` after object key"));
            }
            self.i += 1;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number bytes");
        let x: f64 = text.parse().map_err(|_| self.err(&format!("bad number `{text}`")))?;
        if !x.is_finite() {
            return Err(self.err(&format!("non-finite number `{text}`")));
        }
        Ok(Value::Num(x))
    }

    fn string(&mut self) -> Result<String> {
        self.i += 1; // consume opening quote
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require the low half.
                                self.eat("\\u").map_err(|_| self.err("lone high surrogate"))?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid unicode escape"))?,
                            );
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("raw control character in string")),
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: the input was a valid &str, so
                    // re-decode the sequence starting one byte back.
                    let start = self.i - 1;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let ch = s.chars().next().expect("non-empty remainder");
                    self.i = start + ch.len_utf8();
                    out.push(ch);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-2.5e1").unwrap(), Value::Num(-25.0));
        assert_eq!(Value::parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
        let v = Value::parse("{\"a\": [1, 2, {\"b\": null}], \"c\": false}").unwrap();
        assert_eq!(v.get("c"), Some(&Value::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_malformed_input_with_named_errors() {
        for (input, needle) in [
            ("{not json", "string key"),
            ("", "end of input"),
            ("[1, 2", "expected `,` or `]`"),
            ("{\"a\": 1,}", "string key"),
            ("{\"a\": 1} trailing", "trailing"),
            ("\"unterminated", "unterminated"),
            ("{\"a\": 1, \"a\": 2}", "duplicate key"),
            ("nulL", "expected `null`"),
            ("1e999", "non-finite"),
        ] {
            let err = Value::parse(input).unwrap_err().to_string();
            assert!(err.contains(needle), "input {input:?}: err {err:?}");
        }
    }

    #[test]
    fn depth_cap_rejects_hostile_nesting() {
        let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        let err = Value::parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nesting too deep"), "err: {err}");
    }

    #[test]
    fn render_is_canonical_and_roundtrips() {
        let v = Value::parse("{\"b\":1.500000,\"a\":[1.0, 2],\"s\":\"x\\ty\"}").unwrap();
        let rendered = v.render();
        assert_eq!(rendered, "{\"b\": 1.5, \"a\": [1, 2], \"s\": \"x\\ty\"}");
        // A second round trip is a fixed point.
        assert_eq!(Value::parse(&rendered).unwrap().render(), rendered);
    }

    #[test]
    fn unicode_escapes_and_raw_utf8() {
        assert_eq!(Value::parse("\"\\u00e9\"").unwrap(), Value::Str("é".into()));
        assert_eq!(Value::parse("\"\\ud83d\\ude00\"").unwrap(), Value::Str("😀".into()));
        assert_eq!(Value::parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
        assert!(Value::parse("\"\\ud83d\"").is_err());
    }

    #[test]
    fn u64_accessor_is_strict() {
        assert_eq!(Value::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Value::parse("42.5").unwrap().as_u64(), None);
        assert_eq!(Value::parse("-1").unwrap().as_u64(), None);
    }
}
