//! Simulation-as-a-service: the resident multi-tenant job server.
//!
//! Spawning a process per simulation re-pays predictor construction
//! (artifact resolution, weight loads, buffer allocation) on every run —
//! exactly the cost a DL-based simulator wants amortized, since the
//! paper's throughput case (§3.3) rests on keeping one warm model fed
//! with large batches. `repro serve` instead keeps a daemon resident:
//!
//! - **Warm predictors** ([`registry`]): one live predictor per distinct
//!   [`crate::api::job::JobRequest::predictor_key`], built on first use
//!   and reused by every later job from any client.
//! - **Bounded two-class admission** ([`queue`]): at most
//!   `queue_capacity` queued jobs, high priority before normal, each
//!   job queryable by id through its whole `queued → running →
//!   done | failed` lifecycle.
//! - **Cross-tenant co-batching**: concurrently queued engine-mode jobs
//!   that share a predictor key and engine options execute as ONE
//!   [`crate::coordinator::BatchEngine`] group, multiplexing every
//!   tenant's sub-traces into common accelerator batches. The engine's
//!   deterministic schedule guarantees batch composition cannot change
//!   per-job results, so co-batching is invisible except in throughput.
//! - **Newline-delimited JSON protocol** ([`protocol`]): submit /
//!   status / stats / ping / shutdown, plus streamed progress events.
//!   Malformed input of any kind is a named error line — never a daemon
//!   panic, never a dropped sibling connection.
//!
//! A daemon-run job's final report is byte-identical to the same job run
//! in-process via [`crate::api::Simulation`] (up to wall-clock timing
//! fields; pinned by `tests/server_e2e.rs`).

pub mod json;
pub mod protocol;
pub mod queue;
pub mod registry;

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::api::job::JobRequest;
use crate::api::{ExecMode, SimReport};
use crate::coordinator::{BatchEngine, JobSpec};
use crate::des::SimConfig;
use crate::predictor::LatencyPredictor;
use crate::trace::{InputStats, RecordStore};

use self::json::quote;
use self::protocol::{err_line, read_request_line, LineRead, Request};
use self::queue::{AdmitError, JobSnapshot, JobState, JobTable};
use self::registry::PredictorRegistry;

/// Daemon configuration (`repro serve` flags).
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Maximum queued (not yet running) jobs before submits are
    /// rejected with `queue_full`.
    pub queue_capacity: usize,
    /// Maximum jobs co-batched into one engine group.
    pub max_cobatch: usize,
    /// Suppress per-event stderr logging.
    pub quiet: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { queue_capacity: 64, max_cobatch: 4, quiet: false }
    }
}

/// Shared state each connection thread works against.
struct Shared {
    table: JobTable,
    registry: PredictorRegistry,
    shutdown: AtomicBool,
    addr: SocketAddr,
    quiet: bool,
}

impl Shared {
    fn log(&self, msg: &str) {
        if !self.quiet {
            eprintln!("[serve] {msg}");
        }
    }
}

/// The resident job server. [`bind`](Self::bind) it, then [`run`](Self::run)
/// it on the current thread until a shutdown request drains it.
pub struct JobServer {
    listener: TcpListener,
    shared: Arc<Shared>,
    max_cobatch: usize,
}

impl JobServer {
    /// Bind the listener and set up the (still empty) job table and
    /// predictor registry.
    pub fn bind(addr: &str, opts: ServerOptions) -> Result<JobServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding job server to {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        Ok(JobServer {
            listener,
            shared: Arc::new(Shared {
                table: JobTable::new(opts.queue_capacity),
                registry: PredictorRegistry::new(),
                shutdown: AtomicBool::new(false),
                addr: local,
                quiet: opts.quiet,
            }),
            max_cobatch: opts.max_cobatch.max(1),
        })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports — the
    /// tests bind those).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Serve until a shutdown request: accepts connections (one thread
    /// each), runs the scheduler loop, and on shutdown drains the
    /// in-flight group before returning.
    pub fn run(self) -> Result<()> {
        let JobServer { listener, shared, max_cobatch } = self;
        shared.log(&format!("listening on {}", shared.addr));
        let scheduler = {
            let shared = shared.clone();
            std::thread::spawn(move || scheduler_loop(&shared, max_cobatch))
        };
        for conn in listener.incoming() {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let shared = shared.clone();
            // Connection errors (disconnects, write failures) end that
            // connection's thread only; the daemon and every other
            // tenant are unaffected.
            std::thread::spawn(move || {
                let _ = serve_connection(stream, &shared);
            });
        }
        shared.table.begin_shutdown();
        let _ = scheduler.join();
        shared.log("drained; exiting");
        Ok(())
    }
}

/// Map a request-parsing error message onto the protocol error code:
/// job-description problems are `bad_job`, everything else (JSON or
/// protocol shape) is `bad_request`.
fn error_code(msg: &str) -> &'static str {
    if msg.starts_with("job") {
        "bad_job"
    } else {
        "bad_request"
    }
}

fn serve_connection(stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let line = match read_request_line(&mut reader)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                let msg = format!("request line exceeds {} bytes", protocol::MAX_LINE);
                writeln!(writer, "{}", err_line("line_too_long", &msg))?;
                writer.flush()?;
                continue;
            }
            LineRead::Line(l) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        let request = match Request::parse(&line) {
            Ok(r) => r,
            Err(e) => {
                let msg = format!("{e:#}");
                writeln!(writer, "{}", err_line(error_code(&msg), &msg))?;
                writer.flush()?;
                continue;
            }
        };
        match request {
            Request::Ping => {
                writeln!(writer, "{{\"ok\": true}}")?;
                writer.flush()?;
            }
            Request::Stats => {
                writeln!(writer, "{}", stats_line(shared))?;
                writer.flush()?;
            }
            Request::Status { id } => {
                match shared.table.snapshot(id) {
                    Some(snap) => writeln!(writer, "{}", status_line(&snap))?,
                    None => writeln!(writer, "{}", err_line("not_found", &format!("no job {id}")))?,
                }
                writer.flush()?;
            }
            Request::Submit { job, stream } => {
                if let Err(e) = job.validate() {
                    let msg = format!("{e:#}");
                    writeln!(writer, "{}", err_line("bad_job", &msg))?;
                    writer.flush()?;
                    continue;
                }
                match shared.table.submit(job) {
                    Err(e @ AdmitError::QueueFull { .. }) => {
                        writeln!(writer, "{}", err_line("queue_full", &e.to_string()))?;
                        writer.flush()?;
                    }
                    Err(AdmitError::ShuttingDown) => {
                        writeln!(
                            writer,
                            "{}",
                            err_line("shutting_down", &AdmitError::ShuttingDown.to_string())
                        )?;
                        writer.flush()?;
                    }
                    Ok(id) => {
                        shared.log(&format!("job {id} admitted"));
                        writeln!(writer, "{{\"ok\": true, \"id\": {id}}}")?;
                        writer.flush()?;
                        if stream {
                            // A streaming client that disconnects only
                            // ends the stream; the job keeps running.
                            let _ = stream_events(&shared.table, id, &mut writer);
                        }
                    }
                }
            }
            Request::Shutdown => {
                writeln!(writer, "{{\"ok\": true}}")?;
                writer.flush()?;
                shared.log("shutdown requested");
                shared.shutdown.store(true, Ordering::SeqCst);
                shared.table.begin_shutdown();
                // Wake the accept loop so it observes the flag.
                let _ = TcpStream::connect(shared.addr);
                return Ok(());
            }
        }
    }
}

/// One status response line; the final report is embedded verbatim (it
/// is already canonical single-line JSON).
fn status_line(snap: &JobSnapshot) -> String {
    let mut s = format!(
        "{{\"ok\": true, \"id\": {}, \"state\": {}, \"priority\": {}, \
         \"instructions\": {}, \"total\": {}",
        snap.id,
        quote(snap.state.as_str()),
        quote(snap.priority.as_str()),
        snap.instructions,
        snap.total.map(|t| t.to_string()).unwrap_or_else(|| "null".into()),
    );
    if let Some(e) = &snap.error {
        s.push_str(&format!(", \"error\": {}", quote(e)));
    }
    if let Some(r) = &snap.report_json {
        s.push_str(&format!(", \"report\": {r}"));
    }
    s.push('}');
    s
}

/// The stats response line: job counts by state plus one entry per warm
/// predictor.
fn stats_line(shared: &Shared) -> String {
    let (queued, running, done, failed) = shared.table.counts();
    let preds: Vec<String> = shared
        .registry
        .stats()
        .iter()
        .map(|s| {
            format!(
                "{{\"key\": {}, \"label\": {}, \"jobs\": {}, \"served\": {}}}",
                quote(&s.key),
                quote(&s.label),
                s.jobs,
                s.served
            )
        })
        .collect();
    format!(
        "{{\"ok\": true, \"jobs\": {{\"queued\": {queued}, \"running\": {running}, \
         \"done\": {done}, \"failed\": {failed}}}, \"predictors\": [{}]}}",
        preds.join(", ")
    )
}

/// Push event lines for one job until it completes: a `state` line on
/// every lifecycle change, `progress` lines while running, and a final
/// `done` (with the embedded report) or `failed` line.
fn stream_events(table: &JobTable, id: u64, w: &mut impl Write) -> std::io::Result<()> {
    let mut last_state: Option<JobState> = None;
    let mut last_progress = u64::MAX;
    loop {
        let Some(snap) = table.snapshot(id) else { return Ok(()) };
        if last_state != Some(snap.state) {
            last_state = Some(snap.state);
            match snap.state {
                JobState::Done => {
                    writeln!(
                        w,
                        "{{\"event\": \"done\", \"id\": {id}, \"report\": {}}}",
                        snap.report_json.as_deref().unwrap_or("null")
                    )?;
                    return w.flush();
                }
                JobState::Failed => {
                    writeln!(
                        w,
                        "{{\"event\": \"failed\", \"id\": {id}, \"error\": {}}}",
                        quote(snap.error.as_deref().unwrap_or("unknown error"))
                    )?;
                    return w.flush();
                }
                state => {
                    writeln!(
                        w,
                        "{{\"event\": \"state\", \"id\": {id}, \"state\": {}}}",
                        quote(state.as_str())
                    )?;
                }
            }
        }
        if snap.state == JobState::Running && snap.instructions != last_progress {
            last_progress = snap.instructions;
            writeln!(
                w,
                "{{\"event\": \"progress\", \"id\": {id}, \"instructions\": {}, \"total\": {}}}",
                snap.instructions,
                snap.total.map(|t| t.to_string()).unwrap_or_else(|| "null".into())
            )?;
        }
        w.flush()?;
        table.wait_update(Duration::from_millis(100));
    }
}

/// Pull job groups off the queue until shutdown drains it. A panic in
/// one group (a predictor bug, a malformed artifact) fails that group's
/// jobs and the loop continues — one tenant cannot take the daemon down.
fn scheduler_loop(shared: &Shared, max_cobatch: usize) {
    while let Some(group) = shared.table.next_group(max_cobatch) {
        let outcome = catch_unwind(AssertUnwindSafe(|| run_group(shared, &group)));
        if let Err(panic) = outcome {
            let msg = panic_message(&panic);
            for (id, _, _) in &group {
                if let Some(snap) = shared.table.snapshot(*id) {
                    if matches!(snap.state, JobState::Running | JobState::Queued) {
                        shared.table.fail(*id, format!("internal error: {msg}"));
                    }
                }
            }
        }
    }
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".into()
    }
}

/// Execute one dequeued group against its warm predictor: a lone job
/// replays through [`JobRequest::run_with`]; a co-batch group shares one
/// engine ([`run_cobatch`]).
fn run_group(shared: &Shared, group: &[(u64, JobRequest, Arc<AtomicU64>)]) {
    let predictor = match shared.registry.acquire(&group[0].1, group.len() as u64) {
        Ok(p) => p,
        Err(e) => {
            let msg = format!("{e:#}");
            for (id, _, _) in group {
                shared.table.fail(*id, msg.clone());
            }
            return;
        }
    };
    // A previous panic may have poisoned the lock; the predictor state
    // is still internally consistent (poisoning only records the fact),
    // so recover it rather than wedging every later job on this key.
    let mut guard = match predictor.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if let [(id, job, progress)] = group {
        match job.run_with(guard.as_mut(), Some(progress.clone())) {
            Ok(report) => {
                shared.table.finish(*id, report.to_json_compact());
                shared.log(&format!("job {id} done"));
            }
            Err(e) => {
                shared.table.fail(*id, format!("{e:#}"));
                shared.log(&format!("job {id} failed"));
            }
        }
    } else {
        run_cobatch(shared, guard.as_mut(), group);
    }
}

/// A materialized group member, owning everything its `JobSpec` borrows.
/// The job's input stays behind its [`RecordStore`]: an in-memory store
/// for bench/decoded sources, a windowed mapped store for streaming
/// trace files — so co-resident tenants stop duplicating decoded traces.
struct Prepared {
    id: u64,
    job: JobRequest,
    cfg: SimConfig,
    store: RecordStore<'static>,
    des_cpi: Option<f64>,
    bench: Option<String>,
    input: InputStats,
    progress: Arc<AtomicU64>,
}

/// Run a co-batched group through ONE shared engine: every member's
/// sub-traces multiplex into common predictor batches, and each job
/// still gets its own per-job outcome (engine invariance: batch
/// composition cannot change results).
fn run_cobatch(
    shared: &Shared,
    predictor: &mut dyn LatencyPredictor,
    group: &[(u64, JobRequest, Arc<AtomicU64>)],
) {
    let mut prepared: Vec<Prepared> = Vec::with_capacity(group.len());
    for (id, job, progress) in group {
        let built = job.config.build().and_then(|cfg| {
            let (store, des_cpi, bench, input) = job.materialize_store(&cfg)?;
            Ok((cfg, store, des_cpi, bench, input))
        });
        match built {
            Ok((cfg, store, des_cpi, bench, input)) => {
                shared.table.set_total(*id, store.len() as u64);
                prepared.push(Prepared {
                    id: *id,
                    job: job.clone(),
                    cfg,
                    store,
                    des_cpi,
                    bench,
                    input,
                    progress: progress.clone(),
                });
            }
            // Materialization failures (bad trace path, unreadable file)
            // fail that member alone; the rest of the group still runs.
            Err(e) => shared.table.fail(*id, format!("{e:#}")),
        }
    }
    if prepared.is_empty() {
        return;
    }
    let mut engine = BatchEngine::with_options(predictor, prepared[0].job.engine);
    for p in &prepared {
        engine.submit(JobSpec {
            records: p.store.view(),
            cfg: &p.cfg,
            subtraces: p.job.subtraces.max(1),
            window: p.job.window,
            cfg_feature: p.job.cfg_feature,
            progress: Some(p.progress.clone()),
        });
    }
    match engine.run() {
        Ok(report) => {
            for (k, p) in prepared.iter().enumerate() {
                let mut input = p.input;
                // Streaming members report the residency their cursors
                // actually reached (bounded by subtraces x window).
                if input.window_records > 0 {
                    input.peak_resident_records = p.store.peak_resident_records();
                }
                let sim = SimReport {
                    predictor: p.job.predictor.label(),
                    mode: ExecMode::Engine,
                    bench: p.bench.clone(),
                    config: p.cfg.name.to_string(),
                    outcome: report.jobs[k].clone(),
                    engine: Some(report.stats.clone()),
                    des_cpi: p.des_cpi,
                    input,
                };
                shared.table.finish(p.id, sim.to_json_compact());
                shared.log(&format!("job {} done (co-batched x{})", p.id, prepared.len()));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for p in &prepared {
                shared.table.fail(p.id, msg.clone());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::{JobSource, Priority};
    use crate::api::{PredictorSpec, Simulation};
    use crate::server::json::Value;

    fn shared() -> Shared {
        Shared {
            table: JobTable::new(16),
            registry: PredictorRegistry::new(),
            shutdown: AtomicBool::new(false),
            addr: "127.0.0.1:0".parse().unwrap(),
            quiet: true,
        }
    }

    fn engine_job(bench: &str, n: u64, subtraces: usize) -> JobRequest {
        let mut j = JobRequest::new(
            JobSource::Bench { name: bench.into(), n },
            PredictorSpec::table(8),
        );
        j.subtraces = subtraces;
        j
    }

    #[test]
    fn cobatched_group_matches_direct_runs() {
        // Two tenants, same predictor key, different benches and
        // sub-trace counts: one shared engine must reproduce each job's
        // direct (single-tenant) cycles, windows, and instructions.
        let s = shared();
        let a = s.table.submit(engine_job("gcc", 3_000, 4)).unwrap();
        let b = s.table.submit(engine_job("xz", 2_000, 2)).unwrap();
        let group = s.table.next_group(4).unwrap();
        assert_eq!(group.len(), 2, "same-key engine jobs must co-batch");
        run_group(&s, &group);

        for (id, bench, n, subtraces) in [(a, "gcc", 3_000u64, 4usize), (b, "xz", 2_000, 2)] {
            let snap = s.table.snapshot(id).unwrap();
            assert_eq!(snap.state, JobState::Done, "err: {:?}", snap.error);
            let got = Value::parse(snap.report_json.as_deref().unwrap()).unwrap();
            let direct = Simulation::new()
                .bench(bench, n)
                .predictor(PredictorSpec::table(8))
                .subtraces(subtraces)
                .run()
                .unwrap();
            assert_eq!(
                got.get("cycles").and_then(Value::as_u64),
                Some(direct.outcome.cycles),
                "{bench}: co-batched cycles must match the direct run"
            );
            assert_eq!(
                got.get("instructions").and_then(Value::as_u64),
                Some(direct.outcome.instructions)
            );
            assert_eq!(got.get("bench").and_then(Value::as_str), Some(bench));
            // Progress reached the full instruction count.
            assert_eq!(snap.instructions, n);
        }
        // One warm predictor served both tenants.
        assert_eq!(s.registry.len(), 1);
        assert_eq!(s.registry.stats()[0].jobs, 2);
    }

    #[test]
    fn failed_member_does_not_sink_the_group() {
        let s = shared();
        let good = s.table.submit(engine_job("gcc", 1_000, 2)).unwrap();
        let mut bad = engine_job("gcc", 1_000, 2);
        bad.source = JobSource::TraceFile("/nonexistent/trace.smt".into());
        let bad = s.table.submit(bad).unwrap();
        let group = s.table.next_group(4).unwrap();
        assert_eq!(group.len(), 2);
        run_group(&s, &group);
        assert_eq!(s.table.snapshot(good).unwrap().state, JobState::Done);
        let snap = s.table.snapshot(bad).unwrap();
        assert_eq!(snap.state, JobState::Failed);
        assert!(snap.error.unwrap().contains("trace.smt"));
    }

    #[test]
    fn lone_job_runs_via_run_with_and_matches_direct() {
        let s = shared();
        let mut job = engine_job("leela", 1_500, 1);
        job.window = 500;
        job.priority = Priority::High;
        let id = s.table.submit(job).unwrap();
        let group = s.table.next_group(4).unwrap();
        run_group(&s, &group);
        let snap = s.table.snapshot(id).unwrap();
        assert_eq!(snap.state, JobState::Done);
        let got = Value::parse(snap.report_json.as_deref().unwrap()).unwrap();
        assert_eq!(got.get("mode").and_then(Value::as_str), Some("sequential"));
        let direct = Simulation::new()
            .bench("leela", 1_500)
            .predictor(PredictorSpec::table(8))
            .window(500)
            .run()
            .unwrap();
        assert_eq!(got.get("cycles").and_then(Value::as_u64), Some(direct.outcome.cycles));
    }

    #[test]
    fn status_and_stats_lines_are_valid_json() {
        let s = shared();
        let id = s.table.submit(engine_job("gcc", 100, 1)).unwrap();
        let snap = s.table.snapshot(id).unwrap();
        let v = Value::parse(&status_line(&snap)).unwrap();
        assert_eq!(v.get("state").and_then(Value::as_str), Some("queued"));
        assert_eq!(v.get("total").and_then(Value::as_u64), Some(100));
        let v = Value::parse(&stats_line(&s)).unwrap();
        assert_eq!(v.get("jobs").and_then(|j| j.get("queued")).and_then(Value::as_u64), Some(1));
    }

    #[test]
    fn error_codes_partition_by_message() {
        assert_eq!(error_code("json: trailing characters at byte 3"), "bad_request");
        assert_eq!(error_code("request: unknown cmd \"x\""), "bad_request");
        assert_eq!(error_code("job: unknown field \"sauce\""), "bad_job");
        assert_eq!(error_code("job predictor: missing \"model\""), "bad_job");
    }
}
