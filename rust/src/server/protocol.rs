//! The job server's wire protocol: newline-delimited JSON over TCP.
//!
//! Every request is one line, one JSON object with a `"cmd"` member;
//! every response is one line, one JSON object with an `"ok"` member.
//! A submit with `"stream": true` is followed by additional event lines
//! until the job leaves the system. The line length is bounded
//! ([`MAX_LINE`]) so a hostile client cannot make the daemon buffer
//! without limit — an oversized line is a named error, and the
//! connection stays usable.
//!
//! ```text
//! > {"cmd": "submit", "job": {...}}            < {"ok": true, "id": 3}
//! > {"cmd": "status", "id": 3}                 < {"ok": true, "id": 3, "state": "done", ...}
//! > {"cmd": "stats"}                           < {"ok": true, "jobs": {...}, "predictors": [...]}
//! > {"cmd": "ping"}                            < {"ok": true}
//! > {"cmd": "shutdown"}                        < {"ok": true}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Context, Result};

use crate::api::job::JobRequest;

use super::json::{check_keys, quote, Value};

/// Upper bound on one protocol line in bytes. Large enough for any job
/// description or embedded report (compact reports are a few KiB), small
/// enough to bound a connection's buffering.
pub const MAX_LINE: usize = 1 << 20;

/// A parsed client request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Admit a job; with `stream`, keep the connection open and push
    /// progress events until the job completes.
    Submit {
        /// The job description.
        job: JobRequest,
        /// Stream progress events after the admission response.
        stream: bool,
    },
    /// Query one job's lifecycle state by id.
    Status {
        /// Server-assigned job id.
        id: u64,
    },
    /// Query server-wide counters (queue lengths, warm predictors).
    Stats,
    /// Liveness probe.
    Ping,
    /// Drain and stop the daemon.
    Shutdown,
}

impl Request {
    /// Parse one request line (strict: unknown members and unknown
    /// commands are named errors).
    pub fn parse(line: &str) -> Result<Request> {
        Self::from_value(&Value::parse(line)?)
    }

    /// [`parse`](Self::parse) over an already-parsed [`Value`].
    pub fn from_value(v: &Value) -> Result<Request> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("request: expected a JSON object"))?;
        let cmd = v.get("cmd").and_then(Value::as_str).ok_or_else(|| {
            anyhow!("request: missing \"cmd\" (submit|status|stats|ping|shutdown)")
        })?;
        match cmd {
            "submit" => {
                check_keys(obj, "submit request", &["cmd", "job", "stream"])?;
                let job = JobRequest::from_value(
                    v.get("job").ok_or_else(|| anyhow!("submit request: missing \"job\""))?,
                )?;
                let stream = match v.get("stream") {
                    None => false,
                    Some(s) => s
                        .as_bool()
                        .ok_or_else(|| anyhow!("submit request: \"stream\" must be a bool"))?,
                };
                Ok(Request::Submit { job, stream })
            }
            "status" => {
                check_keys(obj, "status request", &["cmd", "id"])?;
                let id = v
                    .get("id")
                    .and_then(Value::as_u64)
                    .ok_or_else(|| anyhow!("status request: missing integer \"id\""))?;
                Ok(Request::Status { id })
            }
            "stats" => {
                check_keys(obj, "stats request", &["cmd"])?;
                Ok(Request::Stats)
            }
            "ping" => {
                check_keys(obj, "ping request", &["cmd"])?;
                Ok(Request::Ping)
            }
            "shutdown" => {
                check_keys(obj, "shutdown request", &["cmd"])?;
                Ok(Request::Shutdown)
            }
            other => bail!("request: unknown cmd \"{other}\" (submit|status|stats|ping|shutdown)"),
        }
    }
}

/// One request line for a submit (the `repro submit` client and tests
/// build their lines through these, so client and server can't drift).
pub fn submit_request(job: &JobRequest, stream: bool) -> String {
    let mut line = format!("{{\"cmd\": \"submit\", \"job\": {}", job.to_json());
    if stream {
        line.push_str(", \"stream\": true");
    }
    line.push('}');
    line
}

/// One request line for a status query.
pub fn status_request(id: u64) -> String {
    format!("{{\"cmd\": \"status\", \"id\": {id}}}")
}

/// One request line for the stats query.
pub fn stats_request() -> String {
    "{\"cmd\": \"stats\"}".into()
}

/// One request line for the liveness probe.
pub fn ping_request() -> String {
    "{\"cmd\": \"ping\"}".into()
}

/// One request line for the shutdown command.
pub fn shutdown_request() -> String {
    "{\"cmd\": \"shutdown\"}".into()
}

/// One error response line: `{"ok": false, "code": .., "error": ..}`.
/// Codes are stable machine-readable names (`bad_request`, `bad_job`,
/// `line_too_long`, `queue_full`, `shutting_down`, `not_found`).
pub fn err_line(code: &str, msg: &str) -> String {
    format!("{{\"ok\": false, \"code\": {}, \"error\": {}}}", quote(code), quote(msg))
}

/// Outcome of one bounded line read.
#[derive(Debug)]
pub enum LineRead {
    /// The peer closed the connection (including mid-line).
    Eof,
    /// The line exceeded [`MAX_LINE`]; it was drained through its
    /// newline, so the connection remains usable.
    TooLong,
    /// One complete line (newline stripped).
    Line(String),
}

/// Read one newline-terminated line without ever buffering more than
/// [`MAX_LINE`] bytes of it.
pub fn read_request_line(r: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            // EOF. A partial unterminated line is discarded: the peer
            // disconnected mid-request.
            return Ok(LineRead::Eof);
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            if buf.len() + pos > MAX_LINE {
                r.consume(pos + 1);
                return Ok(LineRead::TooLong);
            }
            buf.extend_from_slice(&chunk[..pos]);
            r.consume(pos + 1);
            let line = String::from_utf8(buf).map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "non-utf8 request line")
            })?;
            return Ok(LineRead::Line(line));
        }
        let len = chunk.len();
        if buf.len() + len > MAX_LINE {
            // Already oversized: stop buffering, drain to the newline so
            // the next request starts clean.
            buf.clear();
            r.consume(len);
            loop {
                let chunk = r.fill_buf()?;
                if chunk.is_empty() {
                    return Ok(LineRead::Eof);
                }
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        r.consume(pos + 1);
                        return Ok(LineRead::TooLong);
                    }
                    None => {
                        let len = chunk.len();
                        r.consume(len);
                    }
                }
            }
        }
        buf.extend_from_slice(chunk);
        r.consume(len);
    }
}

/// Client side of one request/response exchange: connect, send `line`,
/// read one response line, parse it. (Streaming submits keep reading
/// from the returned connection instead.)
pub fn roundtrip(addr: &str, line: &str) -> Result<Value> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to job server {addr}"))?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    match read_request_line(&mut reader)? {
        LineRead::Line(resp) => {
            Value::parse(&resp).with_context(|| format!("bad response from {addr}"))
        }
        LineRead::Eof => bail!("job server {addr} closed the connection without responding"),
        LineRead::TooLong => bail!("job server {addr} sent an oversized response line"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::JobSource;
    use crate::api::PredictorSpec;
    use std::io::BufReader;

    fn sample_job() -> JobRequest {
        JobRequest::new(
            JobSource::Bench { name: "gcc".into(), n: 500 },
            PredictorSpec::table(8),
        )
    }

    #[test]
    fn request_builders_parse_back() {
        let job = sample_job();
        match Request::parse(&submit_request(&job, false)).unwrap() {
            Request::Submit { job: j, stream } => {
                assert!(!stream);
                assert_eq!(j.to_json(), job.to_json());
            }
            other => panic!("unexpected {other:?}"),
        }
        match Request::parse(&submit_request(&job, true)).unwrap() {
            Request::Submit { stream, .. } => assert!(stream),
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(Request::parse(&status_request(7)).unwrap(), Request::Status { id: 7 }));
        assert!(matches!(Request::parse(&stats_request()).unwrap(), Request::Stats));
        assert!(matches!(Request::parse(&ping_request()).unwrap(), Request::Ping));
        assert!(matches!(Request::parse(&shutdown_request()).unwrap(), Request::Shutdown));
    }

    #[test]
    fn malformed_requests_are_named_errors() {
        for (line, needle) in [
            ("nonsense", "json:"),
            ("[1]", "expected a JSON object"),
            ("{}", "missing \"cmd\""),
            ("{\"cmd\": \"fly\"}", "unknown cmd \"fly\""),
            ("{\"cmd\": \"ping\", \"x\": 1}", "accepted: cmd"),
            ("{\"cmd\": \"status\"}", "missing integer \"id\""),
            ("{\"cmd\": \"submit\"}", "missing \"job\""),
            ("{\"cmd\": \"submit\", \"job\": {\"sauce\": 1}}", "unknown field \"sauce\""),
        ] {
            let err = Request::parse(line).unwrap_err().to_string();
            assert!(err.contains(needle), "line {line}: err {err:?}");
        }
    }

    #[test]
    fn err_line_is_valid_json() {
        let v = Value::parse(&err_line("bad_request", "oops \"quoted\"")).unwrap();
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));
        assert_eq!(v.get("code").and_then(Value::as_str), Some("bad_request"));
        assert_eq!(v.get("error").and_then(Value::as_str), Some("oops \"quoted\""));
    }

    #[test]
    fn bounded_read_handles_lines_eof_and_oversize() {
        let mut r = BufReader::new(&b"{\"cmd\": \"ping\"}\nrest"[..]);
        assert!(matches!(
            read_request_line(&mut r).unwrap(),
            LineRead::Line(l) if l == "{\"cmd\": \"ping\"}"
        ));
        // "rest" has no newline: disconnect mid-request.
        assert!(matches!(read_request_line(&mut r).unwrap(), LineRead::Eof));

        let mut big = vec![b'x'; MAX_LINE + 1024];
        big.push(b'\n');
        big.extend_from_slice(b"{\"cmd\": \"ping\"}\n");
        let mut r = BufReader::new(&big[..]);
        assert!(matches!(read_request_line(&mut r).unwrap(), LineRead::TooLong));
        // The connection is still usable after the oversized line.
        assert!(matches!(
            read_request_line(&mut r).unwrap(),
            LineRead::Line(l) if l == "{\"cmd\": \"ping\"}"
        ));

        let mut r = BufReader::new(&b""[..]);
        assert!(matches!(read_request_line(&mut r).unwrap(), LineRead::Eof));
    }
}
