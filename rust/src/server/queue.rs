//! Bounded admission queue + job lifecycle table for the job server.
//!
//! Admission is bounded (`capacity` queued jobs; beyond that submits are
//! rejected with a named [`AdmitError`], not buffered without limit) and
//! two-class: high-priority jobs dequeue before any normal job, FIFO
//! within each class. Every admitted job lives in the table through the
//! `queued → running → done | failed` lifecycle and stays queryable by
//! id after completion ([`JobTable::snapshot`]).
//!
//! [`JobTable::next_group`] is where cross-tenant co-batching starts:
//! when the scheduler pops an engine-mode job, every other queued
//! engine-mode job with the same predictor key and engine options rides
//! along in the same group, and the server runs the whole group through
//! ONE shared [`crate::coordinator::BatchEngine`]. The engine's
//! deterministic schedule makes this safe: batch composition cannot
//! change a job's results (pinned by the server's equivalence tests).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::job::{JobRequest, Priority};
use crate::api::ExecMode;

/// Lifecycle state of an admitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting for a scheduler slot.
    Queued,
    /// Executing (or grouped into an executing co-batch).
    Running,
    /// Completed; the report JSON is available.
    Done,
    /// Errored (or cancelled by shutdown); the error string is available.
    Failed,
}

impl JobState {
    /// Stable lowercase name used on the wire.
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// Why a submit was rejected at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// The bounded queue already holds `capacity` queued jobs.
    QueueFull {
        /// The queue's configured capacity.
        capacity: usize,
    },
    /// The server is draining; no new jobs are admitted.
    ShuttingDown,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull { capacity } => {
                write!(f, "job queue full ({capacity} queued jobs)")
            }
            AdmitError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Point-in-time view of one job, queryable by id for the job's whole
/// lifetime (completed jobs stay in the table).
#[derive(Debug, Clone)]
pub struct JobSnapshot {
    /// Server-assigned job id.
    pub id: u64,
    /// Current lifecycle state.
    pub state: JobState,
    /// Admission priority class.
    pub priority: Priority,
    /// Instructions simulated so far (live while running).
    pub instructions: u64,
    /// Total instructions, when knowable (bench sources know up front;
    /// trace files once the run opens them).
    pub total: Option<u64>,
    /// Failure message (failed jobs).
    pub error: Option<String>,
    /// Final report as single-line JSON (done jobs).
    pub report_json: Option<String>,
}

struct Entry {
    job: JobRequest,
    state: JobState,
    priority: Priority,
    progress: Arc<AtomicU64>,
    total: Option<u64>,
    error: Option<String>,
    report_json: Option<String>,
}

struct Inner {
    next_id: u64,
    jobs: HashMap<u64, Entry>,
    high: VecDeque<u64>,
    normal: VecDeque<u64>,
    shutdown: bool,
}

/// The server's job table: bounded two-class admission, blocking
/// scheduler hand-off with co-batch grouping, and lifecycle queries.
/// Every method takes `&self`; the table is shared via `Arc` between
/// the listener threads and the scheduler.
pub struct JobTable {
    capacity: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl JobTable {
    /// A table admitting at most `capacity` queued jobs at a time.
    pub fn new(capacity: usize) -> Self {
        JobTable {
            capacity,
            inner: Mutex::new(Inner {
                next_id: 1,
                jobs: HashMap::new(),
                high: VecDeque::new(),
                normal: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Admit a job, returning its id — or a named rejection when the
    /// queue is full or the server is draining.
    pub fn submit(&self, job: JobRequest) -> Result<u64, AdmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.shutdown {
            return Err(AdmitError::ShuttingDown);
        }
        if inner.high.len() + inner.normal.len() >= self.capacity {
            return Err(AdmitError::QueueFull { capacity: self.capacity });
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let priority = job.priority;
        let total = job.total_instructions();
        inner.jobs.insert(
            id,
            Entry {
                job,
                state: JobState::Queued,
                priority,
                progress: Arc::new(AtomicU64::new(0)),
                total,
                error: None,
                report_json: None,
            },
        );
        match priority {
            Priority::High => inner.high.push_back(id),
            Priority::Normal => inner.normal.push_back(id),
        }
        self.cv.notify_all();
        Ok(id)
    }

    /// Block until work is available, then dequeue the next job group
    /// (at most `max` jobs), marking every member running. The head is
    /// the oldest highest-class job; when it runs in engine mode, queued
    /// engine-mode jobs sharing its predictor key and engine options are
    /// grouped with it for co-batched execution. Returns `None` once the
    /// table is shut down and drained.
    #[allow(clippy::type_complexity)]
    pub fn next_group(&self, max: usize) -> Option<Vec<(u64, JobRequest, Arc<AtomicU64>)>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.high.is_empty() && inner.normal.is_empty() {
                if inner.shutdown {
                    return None;
                }
                inner = self.cv.wait(inner).unwrap();
                continue;
            }
            let head = inner
                .high
                .pop_front()
                .or_else(|| inner.normal.pop_front())
                .expect("non-empty queue");
            let head_job = &inner.jobs[&head].job;
            let mut ids = vec![head];
            if head_job.mode() == ExecMode::Engine && max > 1 {
                let key = head_job.predictor_key();
                let opts = head_job.engine;
                // Scan both classes in dequeue order; matching engine-mode
                // jobs ride along, everything else keeps its queue slot.
                let mut take = |queue: &VecDeque<u64>, jobs: &HashMap<u64, Entry>| {
                    let mut taken = Vec::new();
                    for &id in queue {
                        if ids.len() + taken.len() >= max {
                            break;
                        }
                        let job = &jobs[&id].job;
                        if job.mode() == ExecMode::Engine
                            && job.engine == opts
                            && job.predictor_key() == key
                        {
                            taken.push(id);
                        }
                    }
                    taken
                };
                let mut extra = take(&inner.high, &inner.jobs);
                extra.extend(take(&inner.normal, &inner.jobs));
                inner.high.retain(|id| !extra.contains(id));
                inner.normal.retain(|id| !extra.contains(id));
                ids.extend(extra);
            }
            let group = ids
                .into_iter()
                .map(|id| {
                    let entry = inner.jobs.get_mut(&id).expect("queued id in table");
                    entry.state = JobState::Running;
                    (id, entry.job.clone(), entry.progress.clone())
                })
                .collect();
            self.cv.notify_all();
            return Some(group);
        }
    }

    /// The job's live progress counter (shared with the running
    /// simulation), if the id exists.
    pub fn progress_handle(&self, id: u64) -> Option<Arc<AtomicU64>> {
        self.inner.lock().unwrap().jobs.get(&id).map(|e| e.progress.clone())
    }

    /// Record the job's total instruction count once known (trace-file
    /// sources learn it when the run opens the file).
    pub fn set_total(&self, id: u64, total: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.jobs.get_mut(&id) {
            e.total = Some(total);
        }
        self.cv.notify_all();
    }

    /// Mark the job done with its final report JSON.
    pub fn finish(&self, id: u64, report_json: String) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.jobs.get_mut(&id) {
            e.state = JobState::Done;
            e.report_json = Some(report_json);
        }
        self.cv.notify_all();
    }

    /// Mark the job failed with an error message.
    pub fn fail(&self, id: u64, error: String) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.jobs.get_mut(&id) {
            e.state = JobState::Failed;
            e.error = Some(error);
        }
        self.cv.notify_all();
    }

    /// Point-in-time view of one job, if the id exists.
    pub fn snapshot(&self, id: u64) -> Option<JobSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner.jobs.get(&id).map(|e| JobSnapshot {
            id,
            state: e.state,
            priority: e.priority,
            instructions: e.progress.load(Ordering::Relaxed),
            total: e.total,
            error: e.error.clone(),
            report_json: e.report_json.clone(),
        })
    }

    /// Job counts by state: `(queued, running, done, failed)`.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let inner = self.inner.lock().unwrap();
        let mut c = (0, 0, 0, 0);
        for e in inner.jobs.values() {
            match e.state {
                JobState::Queued => c.0 += 1,
                JobState::Running => c.1 += 1,
                JobState::Done => c.2 += 1,
                JobState::Failed => c.3 += 1,
            }
        }
        c
    }

    /// Stop admitting jobs, fail everything still queued, and wake every
    /// waiter (the scheduler then drains and exits).
    pub fn begin_shutdown(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.shutdown = true;
        let queued: Vec<u64> = inner.high.drain(..).chain(inner.normal.drain(..)).collect();
        for id in queued {
            if let Some(e) = inner.jobs.get_mut(&id) {
                e.state = JobState::Failed;
                e.error = Some("server is shutting down".into());
            }
        }
        self.cv.notify_all();
    }

    /// Whether [`begin_shutdown`](Self::begin_shutdown) has been called.
    pub fn is_shutdown(&self) -> bool {
        self.inner.lock().unwrap().shutdown
    }

    /// Block until any job changes state (or the timeout passes) — the
    /// status-wait and event-stream loops poll through this instead of
    /// spinning.
    pub fn wait_update(&self, timeout: Duration) {
        let inner = self.inner.lock().unwrap();
        let _unused = self.cv.wait_timeout(inner, timeout).unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::JobSource;
    use crate::api::PredictorSpec;

    fn job(bench: &str, subtraces: usize, priority: Priority, seq: usize) -> JobRequest {
        let mut j = JobRequest::new(
            JobSource::Bench { name: bench.into(), n: 100 },
            PredictorSpec::table(seq),
        );
        j.subtraces = subtraces;
        j.priority = priority;
        j
    }

    #[test]
    fn high_priority_dequeues_first_fifo_within_class() {
        let table = JobTable::new(8);
        let a = table.submit(job("gcc", 1, Priority::Normal, 8)).unwrap();
        let b = table.submit(job("xz", 1, Priority::Normal, 8)).unwrap();
        let c = table.submit(job("leela", 1, Priority::High, 8)).unwrap();
        let order: Vec<u64> =
            (0..3).map(|_| table.next_group(4).unwrap()[0].0).collect();
        assert_eq!(order, vec![c, a, b]);
    }

    #[test]
    fn sequential_jobs_never_group() {
        let table = JobTable::new(8);
        table.submit(job("gcc", 1, Priority::Normal, 8)).unwrap();
        table.submit(job("xz", 1, Priority::Normal, 8)).unwrap();
        assert_eq!(table.next_group(4).unwrap().len(), 1);
        assert_eq!(table.next_group(4).unwrap().len(), 1);
    }

    #[test]
    fn engine_jobs_with_shared_predictor_cobatch() {
        let table = JobTable::new(8);
        let a = table.submit(job("gcc", 4, Priority::Normal, 8)).unwrap();
        let b = table.submit(job("xz", 4, Priority::Normal, 16)).unwrap(); // different key
        let c = table.submit(job("leela", 2, Priority::Normal, 8)).unwrap();
        let group = table.next_group(4).unwrap();
        let ids: Vec<u64> = group.iter().map(|(id, _, _)| *id).collect();
        assert_eq!(ids, vec![a, c], "same-key engine jobs group; {b} stays queued");
        assert_eq!(table.snapshot(c).unwrap().state, JobState::Running);
        assert_eq!(table.snapshot(b).unwrap().state, JobState::Queued);
        let group = table.next_group(4).unwrap();
        assert_eq!(group[0].0, b);
    }

    #[test]
    fn cobatch_respects_max_and_options() {
        let table = JobTable::new(8);
        for _ in 0..4 {
            table.submit(job("gcc", 4, Priority::Normal, 8)).unwrap();
        }
        let mut other = job("xz", 4, Priority::Normal, 8);
        other.engine.target_batch = 64; // same key, different engine opts
        let e = table.submit(other).unwrap();
        assert_eq!(table.next_group(3).unwrap().len(), 3);
        assert_eq!(table.next_group(3).unwrap().len(), 1);
        let group = table.next_group(3).unwrap();
        assert_eq!((group[0].0, group.len()), (e, 1));
    }

    #[test]
    fn queue_full_and_shutdown_are_named_rejections() {
        let table = JobTable::new(1);
        table.submit(job("gcc", 1, Priority::Normal, 8)).unwrap();
        let err = table.submit(job("xz", 1, Priority::Normal, 8)).unwrap_err();
        assert_eq!(err, AdmitError::QueueFull { capacity: 1 });
        assert!(err.to_string().contains("queue full"));
        table.begin_shutdown();
        let err = table.submit(job("xz", 1, Priority::Normal, 8)).unwrap_err();
        assert_eq!(err, AdmitError::ShuttingDown);
    }

    #[test]
    fn shutdown_fails_queued_jobs_and_unblocks_scheduler() {
        let table = Arc::new(JobTable::new(4));
        let id = table.submit(job("gcc", 1, Priority::Normal, 8)).unwrap();
        table.next_group(4).unwrap(); // drain it to running
        let waiter = {
            let table = table.clone();
            std::thread::spawn(move || table.next_group(4))
        };
        let queued = table.submit(job("xz", 1, Priority::High, 8)).unwrap();
        // The waiter takes the new job or shutdown drains it; either way
        // the thread must return promptly after begin_shutdown.
        std::thread::sleep(Duration::from_millis(20));
        table.begin_shutdown();
        let group = waiter.join().unwrap();
        match group {
            Some(g) => assert_eq!(g[0].0, queued),
            None => {
                let snap = table.snapshot(queued).unwrap();
                assert_eq!(snap.state, JobState::Failed);
                assert!(snap.error.unwrap().contains("shutting down"));
            }
        }
        assert!(table.next_group(4).is_none(), "drained + shutdown returns None");
        assert_eq!(table.snapshot(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn lifecycle_snapshots_track_state() {
        let table = JobTable::new(4);
        let id = table.submit(job("gcc", 1, Priority::Normal, 8)).unwrap();
        let snap = table.snapshot(id).unwrap();
        assert_eq!(snap.state, JobState::Queued);
        assert_eq!(snap.total, Some(100), "bench sources know their total up front");
        let group = table.next_group(4).unwrap();
        group[0].2.fetch_add(42, Ordering::Relaxed);
        let snap = table.snapshot(id).unwrap();
        assert_eq!((snap.state, snap.instructions), (JobState::Running, 42));
        table.finish(id, "{}".into());
        let snap = table.snapshot(id).unwrap();
        assert_eq!(snap.state, JobState::Done);
        assert_eq!(snap.report_json.as_deref(), Some("{}"));
        assert_eq!(table.counts(), (0, 0, 1, 0));
        assert!(table.snapshot(999).is_none());
    }
}
