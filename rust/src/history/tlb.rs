//! Two-stage TLB with a radix page-table walker (paper Table 2: "2-stage
//! TLBs, 1KB TLB caches"; features: "3 fetch/data table walking levels").
//!
//! The walker models a 3-level radix walk. Each level's page-table entry is
//! itself cached in a per-level walk cache; the per-level *miss* flags are
//! exactly the "table walking levels" features the paper feeds the model.

use super::tagarray::TagArray;
use crate::des::config::TlbParams;

/// Number of radix levels walked on a full TLB miss.
pub const WALK_LEVELS: usize = 3;

/// Result of translating one address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TlbResult {
    /// 0 = L1 TLB hit, 1 = L2 TLB hit, 2 = full walk.
    pub level: u8,
    /// Per-walk-level miss flags (walk access had to go to memory).
    pub walk_miss: [bool; WALK_LEVELS],
}

impl TlbResult {
    /// Number of walk levels that went to memory.
    pub fn walk_misses(&self) -> u32 {
        self.walk_miss.iter().filter(|&&m| m).count() as u32
    }
}

/// Two-stage TLB plus walk caches.
#[derive(Debug, Clone)]
pub struct Tlb {
    l1: TagArray,
    l2: TagArray,
    /// One small cache per walk level (PTEs at that level).
    walk_caches: [TagArray; WALK_LEVELS],
    pub walks: u64,
}

/// 4KiB pages.
const PAGE_SHIFT: u32 = 12;

impl Tlb {
    pub fn new(p: &TlbParams) -> Self {
        let l1_sets = (p.l1_entries / p.ways).max(1);
        let l2_sets = (p.l2_entries / p.ways).max(1);
        Tlb {
            l1: TagArray::new(l1_sets, p.ways, 1 << PAGE_SHIFT),
            l2: TagArray::new(l2_sets, p.ways, 1 << PAGE_SHIFT),
            // Higher levels map exponentially more address space per entry:
            // level 0 = 1GiB regions, 1 = 2MiB, 2 = 4KiB PTE lines (8 PTEs
            // per 64B line -> 32KiB per line).
            walk_caches: [
                TagArray::new(4, 4, 1 << 30),
                TagArray::new(16, 4, 2 << 20),
                TagArray::new(32, 4, 32 << 10),
            ],
            walks: 0,
        }
    }

    /// Translate `addr`; updates all structures.
    pub fn translate(&mut self, addr: u64) -> TlbResult {
        if self.l1.access(addr, false).hit {
            return TlbResult { level: 0, walk_miss: [false; WALK_LEVELS] };
        }
        if self.l2.access(addr, false).hit {
            return TlbResult { level: 1, walk_miss: [false; WALK_LEVELS] };
        }
        // Full walk: touch each level's walk cache.
        self.walks += 1;
        let mut walk_miss = [false; WALK_LEVELS];
        for (i, wc) in self.walk_caches.iter_mut().enumerate() {
            walk_miss[i] = !wc.access(addr, false).hit;
        }
        TlbResult { level: 2, walk_miss }
    }

    /// L1-stage hit rate.
    pub fn l1_hit_rate(&self) -> f64 {
        self.l1.hit_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::config::SimConfig;

    fn tlb() -> Tlb {
        Tlb::new(&SimConfig::default_o3().dtlb)
    }

    #[test]
    fn repeat_page_hits_l1() {
        let mut t = tlb();
        assert_eq!(t.translate(0x1000).level, 2); // cold: full walk
        assert_eq!(t.translate(0x1008).level, 0); // same page
        assert_eq!(t.translate(0x1FFF).level, 0);
        assert_eq!(t.translate(0x2000).level, 2); // next page cold
    }

    #[test]
    fn l2_catches_l1_evictions() {
        let mut t = tlb();
        // Touch more pages than L1 holds (48) but fewer than L2 (128).
        for i in 0..100u64 {
            t.translate(i << 12);
        }
        // Re-touch early pages: should mostly be level <= 1 (L2 TLB), not
        // full walks.
        let mut full_walks = 0;
        for i in 0..100u64 {
            if t.translate(i << 12).level == 2 {
                full_walks += 1;
            }
        }
        assert!(full_walks < 20, "full_walks={full_walks}");
    }

    #[test]
    fn walk_locality_reduces_walk_misses() {
        let mut t = tlb();
        // Dense pages under the same 2MiB region: after the first walk,
        // upper-level walk caches hit.
        let r0 = t.translate(0x4000_0000);
        assert_eq!(r0.walk_misses(), WALK_LEVELS as u32);
        // Far-but-same-1GiB page: level-0 cached, deeper levels miss.
        let r1 = t.translate(0x4000_0000 + (4 << 20));
        assert!(r1.walk_misses() < WALK_LEVELS as u32);
    }
}
