//! Branch predictors for history-context simulation (and the DES).
//!
//! The paper's default O3CPU/A64FX both use gem5's bi-mode predictor, and
//! §5 studies a large bi-mode ("BiMode_l") and TAGE-SC-L. We implement
//! bi-mode at two sizes plus a TAGE-lite with tagged geometric-history
//! tables, all behind one trait so both the DES and the history sim can
//! swap them (Table 5).

use crate::des::config::BpChoice;
use crate::isa::{Inst, OpClass};

/// Direction + target predictor interface. `resolve` both computes whether
/// the prediction was wrong and trains the structures.
pub trait BranchPredictor: Send {
    /// Process one control-flow instruction: predict, compare against the
    /// actual outcome carried by `inst`, train, and return whether the
    /// *frontend would have mispredicted* (direction or target).
    fn resolve(&mut self, inst: &Inst) -> bool;

    /// Lifetime statistics: (lookups, mispredicts).
    fn stats(&self) -> (u64, u64);
}

/// Build a predictor from the config choice.
pub fn make_predictor(
    choice: BpChoice,
    btb_entries: usize,
    ras_entries: usize,
) -> Box<dyn BranchPredictor> {
    match choice {
        BpChoice::BiMode => Box::new(BiMode::new(10, btb_entries / 2, ras_entries)),
        BpChoice::BiModeLarge => Box::new(BiMode::new(14, btb_entries * 4, ras_entries)),
        BpChoice::TageLite => Box::new(TageLite::new(btb_entries, ras_entries)),
    }
}

// ---------------------------------------------------------------------
// Shared target prediction: BTB + return-address stack.
// ---------------------------------------------------------------------

struct Btb {
    tags: Vec<u64>,
    targets: Vec<u64>,
    mask: u64,
}

impl Btb {
    fn new(entries: usize) -> Self {
        let n = entries.next_power_of_two();
        Btb { tags: vec![u64::MAX; n], targets: vec![0; n], mask: (n - 1) as u64 }
    }

    fn predict(&self, pc: u64) -> Option<u64> {
        let i = ((pc >> 2) & self.mask) as usize;
        if self.tags[i] == pc {
            Some(self.targets[i])
        } else {
            None
        }
    }

    fn update(&mut self, pc: u64, target: u64) {
        let i = ((pc >> 2) & self.mask) as usize;
        self.tags[i] = pc;
        self.targets[i] = target;
    }
}

struct Ras {
    stack: Vec<u64>,
    cap: usize,
}

impl Ras {
    fn new(cap: usize) -> Self {
        Ras { stack: Vec::with_capacity(cap), cap }
    }

    fn push(&mut self, ret: u64) {
        if self.stack.len() == self.cap {
            self.stack.remove(0);
        }
        self.stack.push(ret);
    }

    fn pop(&mut self) -> Option<u64> {
        self.stack.pop()
    }
}

/// Target-prediction front half shared by all direction predictors.
/// Returns `true` if the *target* was mispredicted for this instruction
/// (and trains the BTB/RAS).
fn resolve_target(btb: &mut Btb, ras: &mut Ras, inst: &Inst, predicted_taken: bool) -> bool {
    match inst.op {
        OpClass::Call => {
            ras.push(inst.pc + 4);
            let wrong = btb.predict(inst.pc) != Some(inst.target);
            btb.update(inst.pc, inst.target);
            wrong
        }
        OpClass::Ret => {
            let pred = ras.pop();
            pred != Some(inst.target)
        }
        OpClass::Jump | OpClass::IndirectBranch => {
            let wrong = btb.predict(inst.pc) != Some(inst.target);
            btb.update(inst.pc, inst.target);
            wrong
        }
        OpClass::CondBranch => {
            // Target only matters if we predicted taken; not-taken is a
            // fall-through with a known target.
            let wrong = if predicted_taken && inst.taken {
                let w = btb.predict(inst.pc) != Some(inst.target);
                if inst.taken {
                    btb.update(inst.pc, inst.target);
                }
                w
            } else {
                if inst.taken {
                    btb.update(inst.pc, inst.target);
                }
                false
            };
            wrong
        }
        _ => false,
    }
}

// ---------------------------------------------------------------------
// Bi-mode
// ---------------------------------------------------------------------

/// gem5-style bi-mode: a choice PHT selects between a taken-biased and a
/// not-taken-biased direction PHT, both indexed by PC xor global history.
pub struct BiMode {
    choice: Vec<u8>,
    taken: Vec<u8>,
    not_taken: Vec<u8>,
    mask: u64,
    ghr: u64,
    btb: Btb,
    ras: Ras,
    lookups: u64,
    mispredicts: u64,
}

impl BiMode {
    /// `bits`: log2 of table entries (12 -> 4K-entry tables; BiMode_l uses
    /// 14 -> 16K).
    pub fn new(bits: u32, btb_entries: usize, ras_entries: usize) -> Self {
        let n = 1usize << bits;
        BiMode {
            choice: vec![1; n],
            taken: vec![2; n],
            not_taken: vec![1; n],
            mask: (n - 1) as u64,
            ghr: 0,
            btb: Btb::new(btb_entries),
            ras: Ras::new(ras_entries),
            lookups: 0,
            mispredicts: 0,
        }
    }

    fn predict_dir(&self, pc: u64) -> (bool, usize, usize) {
        let ci = ((pc >> 2) & self.mask) as usize;
        let di = (((pc >> 2) ^ self.ghr) & self.mask) as usize;
        let use_taken = self.choice[ci] >= 2;
        let dir = if use_taken { self.taken[di] >= 2 } else { self.not_taken[di] >= 2 };
        (dir, ci, di)
    }

    fn train(&mut self, pc: u64, taken: bool) {
        let (pred, ci, di) = self.predict_dir(pc);
        let use_taken = self.choice[ci] >= 2;
        // Bi-mode update rule: the selected direction table always trains;
        // the choice table trains unless the chosen table was correct while
        // the choice was "wrong-way".
        let dir_table = if use_taken { &mut self.taken } else { &mut self.not_taken };
        bump(&mut dir_table[di], taken);
        if !(pred == taken && use_taken != taken) {
            bump(&mut self.choice[ci], taken);
        }
        self.ghr = (self.ghr << 1) | taken as u64;
    }
}

#[inline]
fn bump(counter: &mut u8, up: bool) {
    if up {
        *counter = (*counter + 1).min(3);
    } else {
        *counter = counter.saturating_sub(1);
    }
}

impl BranchPredictor for BiMode {
    fn resolve(&mut self, inst: &Inst) -> bool {
        self.lookups += 1;
        let (dir_pred, _, _) = self.predict_dir(inst.pc);
        let predicted_taken = match inst.op {
            OpClass::CondBranch => dir_pred,
            _ => true, // unconditional
        };
        let dir_wrong = inst.op == OpClass::CondBranch && dir_pred != inst.taken;
        let target_wrong = resolve_target(&mut self.btb, &mut self.ras, inst, predicted_taken);
        if inst.op == OpClass::CondBranch {
            self.train(inst.pc, inst.taken);
        }
        let wrong = dir_wrong || target_wrong;
        self.mispredicts += wrong as u64;
        wrong
    }

    fn stats(&self) -> (u64, u64) {
        (self.lookups, self.mispredicts)
    }
}

// ---------------------------------------------------------------------
// TAGE-lite
// ---------------------------------------------------------------------

const TAGE_TABLES: usize = 4;
const TAGE_HIST: [u32; TAGE_TABLES] = [5, 15, 44, 130];

struct TageEntry {
    tag: u16,
    ctr: i8, // -4..3, >= 0 means taken
    useful: u8,
}

/// Simplified TAGE: bimodal base + 4 tagged tables with geometric history
/// lengths, usefulness-based allocation. Captures the pattern/loop branches
/// a bimodal misses — the behaviour delta Table 5 measures.
pub struct TageLite {
    base: Vec<u8>,
    base_mask: u64,
    tables: Vec<Vec<TageEntry>>,
    table_mask: u64,
    ghr: u128,
    btb: Btb,
    ras: Ras,
    lookups: u64,
    mispredicts: u64,
    alloc_tick: u64,
}

impl TageLite {
    pub fn new(btb_entries: usize, ras_entries: usize) -> Self {
        let base_n = 1usize << 13;
        let table_n = 1usize << 10;
        TageLite {
            base: vec![2; base_n],
            base_mask: (base_n - 1) as u64,
            tables: (0..TAGE_TABLES)
                .map(|_| {
                    (0..table_n)
                        .map(|_| TageEntry { tag: u16::MAX, ctr: 0, useful: 0 })
                        .collect()
                })
                .collect(),
            table_mask: (table_n - 1) as u64,
            ghr: 0,
            btb: Btb::new(btb_entries),
            ras: Ras::new(ras_entries),
            lookups: 0,
            mispredicts: 0,
            alloc_tick: 0,
        }
    }

    fn fold_history(&self, len: u32) -> u64 {
        // Fold `len` bits of GHR into 20 bits.
        let mut h = self.ghr & ((1u128 << len.min(127)) - 1);
        let mut folded: u64 = 0;
        while h != 0 {
            folded ^= (h & 0xFFFFF) as u64;
            h >>= 20;
        }
        folded
    }

    fn index_tag(&self, pc: u64, t: usize) -> (usize, u16) {
        let f = self.fold_history(TAGE_HIST[t]);
        let idx = (((pc >> 2) ^ f ^ (f >> 7) ^ (t as u64)) & self.table_mask) as usize;
        let tag = (((pc >> 2) ^ (f << 1) ^ (t as u64 * 0x9E37)) & 0xFF) as u16;
        (idx, tag)
    }

    /// Longest-history matching table, if any: (table, index).
    fn find_provider(&self, pc: u64) -> Option<(usize, usize)> {
        for t in (0..TAGE_TABLES).rev() {
            let (idx, tag) = self.index_tag(pc, t);
            if self.tables[t][idx].tag == tag {
                return Some((t, idx));
            }
        }
        None
    }

    fn predict_dir(&self, pc: u64) -> bool {
        if let Some((t, idx)) = self.find_provider(pc) {
            self.tables[t][idx].ctr >= 0
        } else {
            self.base[((pc >> 2) & self.base_mask) as usize] >= 2
        }
    }

    fn train(&mut self, pc: u64, taken: bool, was_correct: bool) {
        let provider = self.find_provider(pc);
        match provider {
            Some((t, idx)) => {
                let e = &mut self.tables[t][idx];
                e.ctr = (e.ctr + if taken { 1 } else { -1 }).clamp(-4, 3);
                if was_correct {
                    e.useful = (e.useful + 1).min(3);
                }
            }
            None => {
                let bi = ((pc >> 2) & self.base_mask) as usize;
                bump(&mut self.base[bi], taken);
            }
        }
        // Allocate a longer-history entry on a mispredict.
        if !was_correct {
            let start = provider.map(|(t, _)| t + 1).unwrap_or(0);
            self.alloc_tick += 1;
            for t in start..TAGE_TABLES {
                let (idx, tag) = self.index_tag(pc, t);
                let e = &mut self.tables[t][idx];
                if e.useful == 0 {
                    e.tag = tag;
                    e.ctr = if taken { 0 } else { -1 };
                    break;
                } else if self.alloc_tick % 8 == 0 {
                    // Periodic useful decay to avoid table lockup.
                    e.useful -= 1;
                }
            }
        }
        self.ghr = (self.ghr << 1) | taken as u128;
    }
}

impl BranchPredictor for TageLite {
    fn resolve(&mut self, inst: &Inst) -> bool {
        self.lookups += 1;
        let dir_pred = self.predict_dir(inst.pc);
        let predicted_taken = match inst.op {
            OpClass::CondBranch => dir_pred,
            _ => true,
        };
        let dir_wrong = inst.op == OpClass::CondBranch && dir_pred != inst.taken;
        let target_wrong = resolve_target(&mut self.btb, &mut self.ras, inst, predicted_taken);
        if inst.op == OpClass::CondBranch {
            self.train(inst.pc, inst.taken, !dir_wrong);
        }
        let wrong = dir_wrong || target_wrong;
        self.mispredicts += wrong as u64;
        wrong
    }

    fn stats(&self) -> (u64, u64) {
        (self.lookups, self.mispredicts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn branch(pc: u64, taken: bool) -> Inst {
        Inst {
            pc,
            op: OpClass::CondBranch,
            target: 0x9000,
            taken,
            ..Default::default()
        }
    }

    #[test]
    fn bimode_learns_biased_branch() {
        let mut bp = BiMode::new(12, 1024, 16);
        let mut wrong_late = 0;
        for i in 0..1000 {
            let w = bp.resolve(&branch(0x1000, true));
            if i >= 100 && w {
                wrong_late += 1;
            }
        }
        assert_eq!(wrong_late, 0, "always-taken branch still mispredicted");
    }

    #[test]
    fn bimode_struggles_with_pattern_tage_learns_it() {
        // Period-3 pattern T T N: bimodal saturates toward taken and eats
        // the N; TAGE's history tables should learn it near-perfectly.
        let run = |bp: &mut dyn BranchPredictor| {
            let mut wrong = 0u64;
            for i in 0..3000u64 {
                let taken = i % 3 != 2;
                let w = bp.resolve(&branch(0x2000, taken));
                if i >= 1500 && w {
                    wrong += 1;
                }
            }
            wrong
        };
        let mut bm = BiMode::new(12, 1024, 16);
        let mut tg = TageLite::new(1024, 16);
        let bm_wrong = run(&mut bm);
        let tg_wrong = run(&mut tg);
        assert!(
            tg_wrong * 3 < bm_wrong.max(1),
            "tage={tg_wrong} bimode={bm_wrong}"
        );
    }

    #[test]
    fn ras_predicts_matched_call_ret() {
        let mut bp = BiMode::new(12, 1024, 16);
        // call from 0x100 -> ret to 0x104
        let call =
            Inst { pc: 0x100, op: OpClass::Call, target: 0x500, taken: true, ..Default::default() };
        let ret =
            Inst { pc: 0x520, op: OpClass::Ret, target: 0x104, taken: true, ..Default::default() };
        bp.resolve(&call); // first call: BTB cold -> may mispredict
        bp.resolve(&call);
        let wrong = bp.resolve(&ret);
        // RAS was pushed twice; top matches 0x104.
        assert!(!wrong, "matched ret should be predicted by RAS");
    }

    #[test]
    fn indirect_branch_with_changing_target_mispredicts() {
        let mut bp = BiMode::new(12, 1024, 16);
        let mut wrong = 0;
        for i in 0..100u64 {
            let inst = Inst {
                pc: 0x300,
                op: OpClass::IndirectBranch,
                target: 0x1000 + (i % 2) * 0x100,
                taken: true,
                ..Default::default()
            };
            if bp.resolve(&inst) {
                wrong += 1;
            }
        }
        assert!(wrong > 90, "alternating indirect target must keep missing: {wrong}");
    }

    #[test]
    fn make_predictor_all_choices() {
        for c in [BpChoice::BiMode, BpChoice::BiModeLarge, BpChoice::TageLite] {
            let mut bp = make_predictor(c, 512, 8);
            for i in 0..200 {
                bp.resolve(&branch(0x40 + (i % 7) * 4, i % 2 == 0));
            }
            let (lookups, miss) = bp.stats();
            assert_eq!(lookups, 200);
            assert!(miss <= 200);
        }
    }
}
