//! Set-associative LRU tag array — the lookup-table core of history-context
//! simulation (paper §2.2: "obtaining these intermediate results mostly
//! involves table lookups (e.g., cache tag array)").
//!
//! Only tags, LRU order, and dirty bits are kept: no data, no MSHRs, no
//! pipeline — those timing effects are the ML model's job.

/// Outcome of a tag-array access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TagAccess {
    /// Did the line hit?
    pub hit: bool,
    /// Did the fill evict a dirty line (i.e. cause a writeback)?
    pub writeback: bool,
}

/// One set-associative, true-LRU tag array with dirty bits.
#[derive(Debug, Clone)]
pub struct TagArray {
    sets: usize,
    ways: usize,
    /// Per-way tags; `u64::MAX` = invalid. Layout: `[set * ways + way]`.
    tags: Vec<u64>,
    /// LRU stamps (bigger = more recent).
    stamps: Vec<u64>,
    dirty: Vec<bool>,
    tick: u64,
    line_shift: u32,
    // statistics
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl TagArray {
    /// Build from geometry. `line` is the block size in bytes used to
    /// derive the tag from an address.
    pub fn new(sets: usize, ways: usize, line: u64) -> Self {
        assert!(sets > 0 && ways > 0 && line.is_power_of_two());
        TagArray {
            sets,
            ways,
            tags: vec![u64::MAX; sets * ways],
            stamps: vec![0; sets * ways],
            dirty: vec![false; sets * ways],
            tick: 0,
            line_shift: line.trailing_zeros(),
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn index(&self, addr: u64) -> (usize, u64) {
        let block = addr >> self.line_shift;
        ((block as usize) % self.sets, block)
    }

    /// Access `addr`; on miss the line is filled (allocate-on-miss),
    /// evicting LRU. `write` marks the line dirty.
    pub fn access(&mut self, addr: u64, write: bool) -> TagAccess {
        self.tick += 1;
        let (set, tag) = self.index(addr);
        let base = set * self.ways;
        let ways = &mut self.tags[base..base + self.ways];
        // Hit path.
        if let Some(w) = ways.iter().position(|&t| t == tag) {
            self.stamps[base + w] = self.tick;
            self.dirty[base + w] |= write;
            self.hits += 1;
            return TagAccess { hit: true, writeback: false };
        }
        // Miss: fill into invalid or LRU way.
        self.misses += 1;
        let victim = (0..self.ways)
            .min_by_key(|&w| {
                if self.tags[base + w] == u64::MAX {
                    0
                } else {
                    self.stamps[base + w] + 1
                }
            })
            .unwrap();
        let evicted_dirty = self.tags[base + victim] != u64::MAX && self.dirty[base + victim];
        if evicted_dirty {
            self.writebacks += 1;
        }
        self.tags[base + victim] = tag;
        self.stamps[base + victim] = self.tick;
        self.dirty[base + victim] = write;
        TagAccess { hit: false, writeback: evicted_dirty }
    }

    /// Probe without filling (used by prefetch checks).
    pub fn probe(&self, addr: u64) -> bool {
        let (set, tag) = self.index(addr);
        let base = set * self.ways;
        self.tags[base..base + self.ways].contains(&tag)
    }

    /// Insert a line without counting it as a demand access (prefetch
    /// fill). Returns whether a dirty line was evicted.
    pub fn fill(&mut self, addr: u64) -> bool {
        let before = (self.hits, self.misses);
        let acc = self.access(addr, false);
        // Undo demand counters: prefetch fills aren't demand traffic.
        self.hits = before.0;
        self.misses = before.1;
        acc.writeback
    }

    /// Hit rate so far (1.0 when no accesses yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_access_hits() {
        let mut t = TagArray::new(64, 4, 64);
        assert!(!t.access(0x1000, false).hit);
        assert!(t.access(0x1000, false).hit);
        assert!(t.access(0x1004, false).hit); // same line
        assert!(!t.access(0x2000, false).hit);
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways: A, B, A, C must evict B (LRU), so A still hits.
        let mut t = TagArray::new(1, 2, 64);
        t.access(0x0, false); // A
        t.access(0x40, false); // B
        t.access(0x0, false); // A (refreshes)
        t.access(0x80, false); // C -> evicts B
        assert!(t.access(0x0, false).hit, "A evicted but was MRU");
        assert!(!t.access(0x40, false).hit, "B should have been evicted");
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut t = TagArray::new(1, 1, 64);
        t.access(0x0, true); // dirty
        let acc = t.access(0x40, false); // evicts dirty line
        assert!(acc.writeback);
        assert_eq!(t.writebacks, 1);
        let acc2 = t.access(0x80, false); // evicts clean line
        assert!(!acc2.writeback);
    }

    #[test]
    fn working_set_behavior() {
        // A working set that fits never misses after warmup; one that
        // doesn't fit thrashes.
        let mut small = TagArray::new(64, 4, 64); // 16KB
        for round in 0..4 {
            for i in 0..128u64 {
                let acc = small.access(i * 64, false);
                if round > 0 {
                    assert!(acc.hit, "fit working set missed at {i}");
                }
            }
        }
        let mut big = TagArray::new(4, 1, 64); // 256B, direct-mapped
        let mut misses = 0;
        for _ in 0..4 {
            for i in 0..64u64 {
                if !big.access(i * 64, false).hit {
                    misses += 1;
                }
            }
        }
        assert!(misses > 200, "thrashing set should keep missing: {misses}");
    }

    #[test]
    fn probe_and_fill() {
        let mut t = TagArray::new(16, 2, 64);
        assert!(!t.probe(0x1000));
        t.fill(0x1000);
        assert!(t.probe(0x1000));
        // fill doesn't move demand counters
        assert_eq!(t.hits + t.misses, 0);
    }
}
