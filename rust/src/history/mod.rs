//! History-context simulation (paper §2.2, "Modeling History Context
//! through Simplified Simulation").
//!
//! Caches, TLBs, and branch predictors depend on long-term execution
//! history that is too large to hand to an ML model. SimNet instead runs a
//! *lightweight* simulation of exactly these lookup structures — tag
//! arrays and predictor tables, no pipelines, no MSHRs — and feeds the ML
//! model only the distilled results: which level served each access,
//! whether branch prediction failed, which page-walk levels missed, and
//! how many writebacks were generated. This module is that simulation.
//!
//! The same components back the reference DES's hit/miss decisions, so the
//! features recorded in traces are bit-identical to what the ML simulator
//! would compute online — the property the paper relies on when it embeds
//! history results in gem5-generated traces (§3.2).

pub mod branch;
pub mod tagarray;
pub mod tlb;

use crate::des::config::SimConfig;
use crate::isa::Inst;
use branch::{make_predictor, BranchPredictor};
use tagarray::TagArray;
use tlb::{Tlb, WALK_LEVELS};

/// Distilled history-context results for one instruction — the last row of
/// paper Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HistoryInfo {
    /// Branch misprediction flag (control-flow ops only).
    pub mispredict: bool,
    /// Cache level that served the fetch: 1 = L1I, 2 = L2, 3 = memory.
    pub fetch_level: u8,
    /// Fetch-side page-walk level miss flags.
    pub fetch_walk: [bool; WALK_LEVELS],
    /// Writebacks caused by the fetch: [L1-level, L2-level].
    pub fetch_wb: [bool; 2],
    /// Cache level that served the data access (0 = not a memory op).
    pub data_level: u8,
    /// Data-side page-walk level miss flags.
    pub data_walk: [bool; WALK_LEVELS],
    /// Writebacks caused by the data access: [L1D, L2, prefetch-induced].
    pub data_wb: [bool; 3],
}

/// Per-PC stride-prefetcher entry.
#[derive(Debug, Clone, Copy, Default)]
struct StrideEntry {
    last_addr: u64,
    stride: i64,
    confidence: u8,
}

/// The lightweight history simulator.
pub struct HistorySim {
    cfg: SimConfig,
    l1i: TagArray,
    l1d: TagArray,
    l2: TagArray,
    itlb: Tlb,
    dtlb: Tlb,
    bp: Box<dyn BranchPredictor>,
    prefetch_table: Vec<StrideEntry>,
    /// Instructions processed.
    pub count: u64,
}

impl HistorySim {
    pub fn new(cfg: &SimConfig) -> Self {
        HistorySim {
            l1i: TagArray::new(cfg.l1i.sets(), cfg.l1i.ways, cfg.l1i.line),
            l1d: TagArray::new(cfg.l1d.sets(), cfg.l1d.ways, cfg.l1d.line),
            l2: TagArray::new(cfg.l2.sets(), cfg.l2.ways, cfg.l2.line),
            itlb: Tlb::new(&cfg.itlb),
            dtlb: Tlb::new(&cfg.dtlb),
            bp: make_predictor(cfg.bp, cfg.btb_entries, cfg.ras_entries),
            prefetch_table: vec![StrideEntry::default(); 256],
            count: 0,
            cfg: cfg.clone(),
        }
    }

    /// Process one dynamic instruction in program order; returns the
    /// distilled history features.
    pub fn process(&mut self, inst: &Inst) -> HistoryInfo {
        self.count += 1;
        let mut info = HistoryInfo::default();

        // ---- instruction fetch ----
        let itr = self.itlb.translate(inst.pc);
        info.fetch_walk = itr.walk_miss;
        let ia = self.l1i.access(inst.pc, false);
        if ia.hit {
            info.fetch_level = 1;
        } else {
            let l2a = self.l2.access(inst.pc, false);
            info.fetch_level = if l2a.hit { 2 } else { 3 };
            info.fetch_wb = [ia.writeback, l2a.writeback];
        }

        // ---- data access ----
        if inst.op.is_mem() {
            let dtr = self.dtlb.translate(inst.mem_addr);
            info.data_walk = dtr.walk_miss;
            let is_store = inst.op.is_store();
            let da = self.l1d.access(inst.mem_addr, is_store);
            if da.hit {
                info.data_level = 1;
            } else {
                let l2a = self.l2.access(inst.mem_addr, false);
                info.data_level = if l2a.hit { 2 } else { 3 };
                info.data_wb[0] = da.writeback;
                info.data_wb[1] = l2a.writeback;
            }
            if self.cfg.l1d_prefetch.enabled {
                info.data_wb[2] = self.run_prefetcher(inst.pc, inst.mem_addr);
            }
        }

        // ---- branch prediction ----
        if inst.op.is_control() {
            info.mispredict = self.bp.resolve(inst);
        }

        info
    }

    /// Stride prefetcher: on a stable stride at this PC, pre-fill the next
    /// `degree` lines into L1D/L2. Returns whether any prefetch fill caused
    /// a writeback.
    fn run_prefetcher(&mut self, pc: u64, addr: u64) -> bool {
        let e = &mut self.prefetch_table[((pc >> 2) & 0xFF) as usize];
        let stride = addr as i64 - e.last_addr as i64;
        let mut caused_wb = false;
        if stride == e.stride && stride != 0 {
            e.confidence = (e.confidence + 1).min(3);
        } else {
            e.confidence = e.confidence.saturating_sub(1);
            if e.confidence == 0 {
                e.stride = stride;
            }
        }
        if e.confidence >= 2 {
            let line = self.cfg.l1d.line as i64;
            let stride = e.stride.clamp(-4 * line, 4 * line);
            let degree = self.cfg.l1d_prefetch.degree as i64;
            let addr_i = addr as i64;
            e.last_addr = addr;
            for d in 1..=degree {
                let target = addr_i + stride * d;
                if target > 0 {
                    let t = target as u64;
                    if !self.l1d.probe(t) {
                        caused_wb |= self.l1d.fill(t);
                        caused_wb |= self.l2.fill(t);
                    }
                }
            }
            return caused_wb;
        }
        e.last_addr = addr;
        caused_wb
    }

    /// (lookups, mispredicts) of the branch predictor.
    pub fn bp_stats(&self) -> (u64, u64) {
        self.bp.stats()
    }

    /// Demand hit rates: (L1I, L1D, L2).
    pub fn cache_hit_rates(&self) -> (f64, f64, f64) {
        (self.l1i.hit_rate(), self.l1d.hit_rate(), self.l2.hit_rate())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;
    use crate::workload::{find, suite};

    fn load(addr: u64) -> Inst {
        Inst { pc: 0x1000, op: OpClass::Load, mem_addr: addr, mem_size: 8, ..Default::default() }
    }

    #[test]
    fn fetch_levels_reflect_locality() {
        let mut h = HistorySim::new(&SimConfig::default_o3());
        let i1 = Inst { pc: 0x40_0000, ..Default::default() };
        let first = h.process(&i1);
        assert_eq!(first.fetch_level, 3, "cold fetch goes to memory");
        let again = h.process(&i1);
        assert_eq!(again.fetch_level, 1, "warm fetch hits L1I");
    }

    #[test]
    fn data_levels_reflect_reuse() {
        let mut h = HistorySim::new(&SimConfig::default_o3());
        assert_eq!(h.process(&load(0x1234_0000)).data_level, 3);
        assert_eq!(h.process(&load(0x1234_0008)).data_level, 1, "same line");
    }

    #[test]
    fn l2_level_when_l1_evicted() {
        let cfg = SimConfig::default_o3();
        let mut h = HistorySim::new(&cfg);
        // Fill far more than L1D (32KB) but less than L2 (1MB).
        let lines = (cfg.l1d.size / cfg.l1d.line) * 8;
        for i in 0..lines {
            h.process(&load(0x1000_0000 + i * cfg.l1d.line));
        }
        // Early lines evicted from L1D but still in L2.
        let r = h.process(&load(0x1000_0000));
        assert_eq!(r.data_level, 2, "expected L2 hit, got {}", r.data_level);
    }

    #[test]
    fn non_mem_ops_have_no_data_access() {
        let mut h = HistorySim::new(&SimConfig::default_o3());
        let r = h.process(&Inst { pc: 0x100, op: OpClass::IntAlu, ..Default::default() });
        assert_eq!(r.data_level, 0);
        assert!(!r.mispredict);
    }

    #[test]
    fn prefetcher_promotes_streaming_to_l1_hits() {
        let run = |enabled: bool| {
            let mut cfg = SimConfig::a64fx();
            cfg.l1d_prefetch.enabled = enabled;
            let mut h = HistorySim::new(&cfg);
            let mut hits = 0;
            for i in 0..4000u64 {
                let r = h.process(&load(0x4000_0000 + i * 256)); // line-stride stream
                if i > 100 && r.data_level == 1 {
                    hits += 1;
                }
            }
            hits
        };
        let with = run(true);
        let without = run(false);
        assert!(with > without + 1000, "prefetch hits={with} baseline={without}");
    }

    #[test]
    fn runs_on_real_workload_streams() {
        let cfg = SimConfig::default_o3();
        for b in suite().iter().take(3) {
            let wl = b.workload(0);
            let mut h = HistorySim::new(&cfg);
            let mut mispredicts = 0u64;
            let mut l3 = 0u64;
            for inst in wl.stream().take(50_000) {
                let info = h.process(&inst);
                mispredicts += info.mispredict as u64;
                l3 += (info.data_level == 3) as u64;
            }
            // Sanity: some but not all branches mispredict; some accesses
            // reach memory.
            let (lookups, miss) = h.bp_stats();
            assert!(lookups > 1000, "{}: too few branches", b.name);
            assert!(miss > 0 && miss < lookups, "{}: degenerate bp", b.name);
            assert!(l3 > 0, "{}: no memory-level accesses", b.name);
            assert_eq!(miss, mispredicts);
        }
    }

    #[test]
    fn branchy_workload_mispredicts_more_than_streaming() {
        let cfg = SimConfig::default_o3();
        let rate = |name: &str| {
            let b = find(name).unwrap();
            let wl = b.workload(0);
            let mut h = HistorySim::new(&cfg);
            for inst in wl.stream().take(100_000) {
                h.process(&inst);
            }
            let (l, m) = h.bp_stats();
            m as f64 / l.max(1) as f64
        };
        let branchy = rate("specrand_i");
        let streaming = rate("lbm");
        assert!(branchy > streaming, "branchy={branchy:.3} streaming={streaming:.3}");
    }
}
