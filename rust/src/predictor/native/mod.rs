//! Native pure-Rust NN inference backend (no PJRT, no stub).
//!
//! Implements the paper's latency-predictor forward pass directly over
//! the `.smw` weight tensors: `(n, seq_len, NUM_FEATURES)` encoded rows →
//! hidden blocks → the 33-wide hybrid head decoded by
//! [`crate::runtime::decode_row`]. Supported architectures are the
//! matmul-representable rows of Table 4 (`fc2`, `fc3`, `c1`, `c3`, `rb`);
//! the recurrent/attention models (`lstm2`, `ithemal_lstm2`, `tx2`) stay
//! on the PJRT backend.
//!
//! Perf-relevant design:
//! * The layer plan is compiled once at load time from the actual tensor
//!   shapes (names and order validated against the `.export` manifest),
//!   so the forward pass is a flat loop with no per-batch dispatch.
//! * Every weight matrix is additionally repacked at plan-compile time
//!   into the blocked row-panel layout of [`kernels::PackedMat`]; the
//!   forward pass dispatches per row group between the zero-skip scalar
//!   kernel and the cache-blocked register tiles ([`kernels::dense_auto`]).
//! * Forward/scratch buffers are preallocated and grow-only — steady
//!   state runs allocation-free regardless of batch size. Buffer capacity
//!   is derived from the compiled plan's `max_width`, never from caller
//!   batch history, so [`NativePredictor::clone_lite`] handles size
//!   themselves correctly whatever batches their parent ran.
//! * [`NativePredictor::clone_lite`] hands out per-thread handles that
//!   share one read-only weight arena behind an [`Arc`]; only the scratch
//!   buffers (a few KB) are per-handle, so pool workers never duplicate
//!   weights. [`LatencyPredictor::fork`] exposes the same thing through
//!   the trait so the engine can give every encode worker its own handle.

mod fastmath;
pub mod kernels;

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::features::NUM_FEATURES;
use crate::runtime::{decode_row, read_model_mode, ExportManifest, OutputMode, HEAD_OUT};
use crate::tensor::{Tensor, TensorFile};

use super::{export_name, LatencyPredictor, WeightsSource};

/// Architectures the native backend can lower to its dense kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Fc2,
    Fc3,
    C1,
    C3,
    Rb,
}

impl Arch {
    /// Parse a base architecture name (see [`export_name`]).
    pub fn parse(base: &str) -> Result<Arch> {
        Ok(match base {
            "fc2" => Arch::Fc2,
            "fc3" => Arch::Fc3,
            "c1" => Arch::C1,
            "c3" => Arch::C3,
            "rb" => Arch::Rb,
            other => bail!(
                "native backend does not support architecture {other:?} \
                 (supported: fc2 fc3 c1 c3 rb; lstm2/ithemal_lstm2/tx2 need the PJRT backend)"
            ),
        })
    }

    /// Channel widths of the k2s2 conv stack (empty for the FC models).
    fn conv_channels(self) -> &'static [usize] {
        match self {
            Arch::Fc2 | Arch::Fc3 => &[],
            Arch::C1 => &[64],
            Arch::C3 | Arch::Rb => &[64, 96, 128],
        }
    }

    /// Whether each conv stage is followed by a residual block (RB7).
    fn has_residual(self) -> bool {
        matches!(self, Arch::Rb)
    }

    /// Hidden widths of the FC tail (mirror of python `param_specs`).
    fn fc_hidden(self) -> &'static [usize] {
        match self {
            Arch::Fc2 => &[256],
            Arch::Fc3 => &[512, 256],
            Arch::C1 | Arch::C3 | Arch::Rb => &[256],
        }
    }
}

/// One step of the compiled layer plan. Weight/bias fields are indices
/// into the model's tensor arena; per-item geometry is precomputed so the
/// forward loop does no shape math.
enum Layer {
    /// `relu?(x @ w + b)` over `n` flattened item rows.
    Dense { w: usize, b: usize, relu: bool },
    /// k2s2 conv = dense over `n * pairs` position-pair rows.
    Conv { w: usize, b: usize, pairs: usize },
    /// `relu(x + relu(x @ w1 + b1) @ w2 + b2)` over `n * rows` positions
    /// of width `c`.
    Residual { w1: usize, b1: usize, w2: usize, b2: usize, rows: usize, c: usize },
}

/// The read-only weight arena + compiled layer plan one or more
/// [`NativePredictor`] handles share through an [`Arc`].
pub struct NativeModel {
    tag: String,
    seq: usize,
    mode: OutputMode,
    tensors: Vec<Tensor>,
    /// Blocked-panel repack of every 2-D tensor (index-aligned with
    /// `tensors`; `None` for biases). Built once at load time.
    packed: Vec<Option<kernels::PackedMat>>,
    layers: Vec<Layer>,
    /// Largest per-item activation width across layers (buffer sizing).
    max_width: usize,
    /// Where the weights came from, for diagnostics.
    weights_from: String,
}

/// Ordered `(name, dims)` parameter list for an architecture at a given
/// sequence length — mirror of python `compile.model.param_specs` for the
/// architectures the native backend supports.
pub fn param_specs(arch: Arch, seq: usize) -> Vec<(String, Vec<usize>)> {
    let mut specs = Vec::new();
    let mut width = NUM_FEATURES;
    let mut length = seq;
    for (i, &c_out) in arch.conv_channels().iter().enumerate() {
        specs.push((format!("conv{i}/w"), vec![2 * width, c_out]));
        specs.push((format!("conv{i}/b"), vec![c_out]));
        length /= 2;
        if arch.has_residual() {
            specs.push((format!("res{i}/w1"), vec![c_out, c_out]));
            specs.push((format!("res{i}/b1"), vec![c_out]));
            specs.push((format!("res{i}/w2"), vec![c_out, c_out]));
            specs.push((format!("res{i}/b2"), vec![c_out]));
        }
        width = c_out;
    }
    let mut flat = if arch.conv_channels().is_empty() {
        seq * NUM_FEATURES
    } else {
        width * length
    };
    for (i, &h) in arch.fc_hidden().iter().enumerate() {
        specs.push((format!("fc{i}/w"), vec![flat, h]));
        specs.push((format!("fc{i}/b"), vec![h]));
        flat = h;
    }
    specs.push(("out/w".to_string(), vec![flat, HEAD_OUT]));
    specs.push(("out/b".to_string(), vec![HEAD_OUT]));
    specs
}

/// Sequential tensor reader used by [`plan`]: enforces name order and
/// dimensionality with errors that say which tensor broke the contract.
struct Cursor<'a> {
    tensors: &'a [Tensor],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, name: &str, ndim: usize) -> Result<(usize, &'a Tensor)> {
        let t = self.tensors.get(self.pos).ok_or_else(|| {
            anyhow!("missing tensor {name} (weights file has only {})", self.tensors.len())
        })?;
        if t.name != name {
            bail!("tensor {} out of order: expected {name}, found {}", self.pos, t.name);
        }
        if t.dims.len() != ndim {
            bail!("tensor {name}: expected {ndim} dims, found {:?}", t.dims);
        }
        let idx = self.pos;
        self.pos += 1;
        Ok((idx, t))
    }
}

/// Compile the layer plan for `arch` from the actual tensor shapes.
/// Hidden widths come from the tensors (so tiny test fixtures work); only
/// the structure — layer kinds, names, order, shape chaining from
/// `NUM_FEATURES` to [`HEAD_OUT`] — is enforced. Returns the plan and the
/// largest per-item activation width.
fn plan(arch: Arch, seq: usize, tensors: &[Tensor]) -> Result<(Vec<Layer>, usize)> {
    if seq == 0 {
        bail!("native model needs seq_len >= 1");
    }
    let mut cur = Cursor { tensors, pos: 0 };
    let mut layers = Vec::new();
    let mut width = NUM_FEATURES;
    let mut length = seq;
    let mut max_width = seq * NUM_FEATURES;
    for (i, _) in arch.conv_channels().iter().enumerate() {
        if length < 2 || length % 2 != 0 {
            bail!("conv{i}: length {length} not divisible by 2 (seq_len {seq} too small)");
        }
        let (wi, wt) = cur.take(&format!("conv{i}/w"), 2)?;
        if wt.dims[0] != 2 * width {
            bail!("conv{i}/w: input dim {} != 2 * {width}", wt.dims[0]);
        }
        let c_out = wt.dims[1];
        let (bi, bt) = cur.take(&format!("conv{i}/b"), 1)?;
        if bt.dims[0] != c_out {
            bail!("conv{i}/b: width {} != {c_out}", bt.dims[0]);
        }
        length /= 2;
        layers.push(Layer::Conv { w: wi, b: bi, pairs: length });
        width = c_out;
        max_width = max_width.max(length * width);
        if arch.has_residual() {
            let (w1, t1) = cur.take(&format!("res{i}/w1"), 2)?;
            let (b1, u1) = cur.take(&format!("res{i}/b1"), 1)?;
            let (w2, t2) = cur.take(&format!("res{i}/w2"), 2)?;
            let (b2, u2) = cur.take(&format!("res{i}/b2"), 1)?;
            if t1.dims != [width, width]
                || t2.dims != [width, width]
                || u1.dims != [width]
                || u2.dims != [width]
            {
                bail!("res{i}: expected square [{width}, {width}] transforms");
            }
            layers.push(Layer::Residual { w1, b1, w2, b2, rows: length, c: width });
        }
    }
    let mut flat = if arch.conv_channels().is_empty() {
        seq * NUM_FEATURES
    } else {
        width * length
    };
    for (i, _) in arch.fc_hidden().iter().enumerate() {
        let (wi, wt) = cur.take(&format!("fc{i}/w"), 2)?;
        if wt.dims[0] != flat {
            bail!("fc{i}/w: input dim {} does not match activation width {flat}", wt.dims[0]);
        }
        let h = wt.dims[1];
        let (bi, bt) = cur.take(&format!("fc{i}/b"), 1)?;
        if bt.dims[0] != h {
            bail!("fc{i}/b: width {} != {h}", bt.dims[0]);
        }
        layers.push(Layer::Dense { w: wi, b: bi, relu: true });
        flat = h;
        max_width = max_width.max(h);
    }
    let (wi, wt) = cur.take("out/w", 2)?;
    if wt.dims[0] != flat || wt.dims[1] != HEAD_OUT {
        bail!("out/w: expected [{flat}, {HEAD_OUT}], found {:?}", wt.dims);
    }
    let (bi, bt) = cur.take("out/b", 1)?;
    if bt.dims[0] != HEAD_OUT {
        bail!("out/b: width {} != {HEAD_OUT}", bt.dims[0]);
    }
    layers.push(Layer::Dense { w: wi, b: bi, relu: false });
    max_width = max_width.max(HEAD_OUT);
    if cur.pos != tensors.len() {
        bail!("unexpected trailing tensor {} after out/b", tensors[cur.pos].name);
    }
    Ok((layers, max_width))
}

/// xorshift64* step mapped to `[0, 1)` (24-bit resolution, exact in f32).
fn unit(state: &mut u64) -> f32 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    ((x >> 40) as f32) / (1u64 << 24) as f32
}

/// Deterministic fallback weights (glorot-uniform, seeded from the tag)
/// so the native backend runs with zero artifacts on disk. This is NOT
/// the python training init — real accuracy needs trained `.smw` weights;
/// generated weights exist for plumbing/throughput tests and CI smoke.
fn init_tensors(arch: Arch, seq: usize, tag: &str) -> Vec<Tensor> {
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    for byte in tag.bytes() {
        state = (state ^ u64::from(byte)).wrapping_mul(0x100_0000_01B3);
    }
    param_specs(arch, seq)
        .into_iter()
        .map(|(name, dims)| {
            let len: usize = dims.iter().product();
            let data = if dims.len() == 1 {
                vec![0.0f32; len] // biases start at zero, like python init
            } else {
                let limit = (6.0 / (dims[0] + dims[1]) as f32).sqrt();
                (0..len).map(|_| (unit(&mut state) * 2.0 - 1.0) * limit).collect()
            };
            Tensor::new(name, dims, data)
        })
        .collect()
}

/// Repack every weight matrix into the blocked row-panel layout the
/// tiled kernels stream (biases and other 1-D tensors stay unpacked).
fn pack_weights(tensors: &[Tensor]) -> Vec<Option<kernels::PackedMat>> {
    tensors
        .iter()
        .map(|t| match t.dims.as_slice() {
            [d_in, d_out] => Some(kernels::PackedMat::pack(&t.data, *d_in, *d_out)),
            _ => None,
        })
        .collect()
}

/// Pure-Rust latency predictor: an [`Arc`]-shared [`NativeModel`] plus
/// per-handle scratch buffers.
pub struct NativePredictor {
    model: Arc<NativeModel>,
    /// Ping-pong activation buffers (grow-only, reused across batches).
    prev: Vec<f32>,
    next: Vec<f32>,
    /// Residual-branch scratch.
    tmp: Vec<f32>,
    /// Raw head rows of the current batch.
    head: Vec<f32>,
    served: u64,
}

impl NativePredictor {
    /// Load model `tag` from `artifacts`. The `<base>.export` manifest
    /// (when present) fixes `seq_len` and the expected weight-tensor
    /// names; without one, `fallback_seq` is used. Weights resolve per
    /// `weights` ([`WeightsSource`]); the output mode comes from
    /// `<base>.meta` as on the PJRT path.
    ///
    /// # Examples
    ///
    /// ```no_run
    /// use std::path::Path;
    /// use simnet::predictor::{LatencyPredictor, NativePredictor, WeightsSource};
    ///
    /// let mut p = NativePredictor::load(
    ///     Path::new("artifacts"),
    ///     "fc2",
    ///     &WeightsSource::Auto, // tag.smw, base.smw, base.init.smw, else init
    ///     8,                    // seq_len fallback when no .export manifest
    /// )?;
    /// println!("{} from {}", p.tag(), p.weights_from());
    /// let inputs = vec![0.0f32; p.seq_len() * simnet::features::NUM_FEATURES];
    /// let triples = p.predict(&inputs, 1)?;
    /// assert_eq!(triples.len(), 1);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn load(
        artifacts: &Path,
        tag: &str,
        weights: &WeightsSource,
        fallback_seq: usize,
    ) -> Result<Self> {
        let base = export_name(tag);
        let arch = Arch::parse(&base)?;
        let manifest_path = artifacts.join(format!("{base}.export"));
        let manifest = if manifest_path.exists() {
            Some(ExportManifest::read(&manifest_path)?)
        } else {
            None
        };
        let seq = manifest.as_ref().map(|m| m.seq_len).unwrap_or(fallback_seq);

        let weights_path = match weights {
            WeightsSource::Path(p) => Some(p.clone()),
            WeightsSource::Auto => [
                artifacts.join(format!("{tag}.smw")),
                artifacts.join(format!("{base}.smw")),
                artifacts.join(format!("{base}.init.smw")),
            ]
            .into_iter()
            .find(|p| p.exists()),
            WeightsSource::Init => None,
        };
        let (tensors, weights_from) = match weights_path {
            Some(p) => {
                let tf = TensorFile::read(&p)
                    .with_context(|| format!("reading weights {}", p.display()))?;
                (tf.tensors, p.display().to_string())
            }
            None => (init_tensors(arch, seq, tag), "init(generated)".to_string()),
        };
        if let Some(m) = &manifest {
            if !m.weights.is_empty() {
                let names: Vec<&str> = tensors.iter().map(|t| t.name.as_str()).collect();
                let expect: Vec<&str> = m.weights.iter().map(|s| s.as_str()).collect();
                if names != expect {
                    bail!(
                        "weights {weights_from} do not match manifest {}: got {names:?}, \
                         expected {expect:?}",
                        manifest_path.display()
                    );
                }
            }
        }
        let (layers, max_width) =
            plan(arch, seq, &tensors).with_context(|| format!("native model {tag}"))?;
        let mode = read_model_mode(artifacts, &base).unwrap_or(OutputMode::Hybrid);
        let packed = pack_weights(&tensors);
        Ok(Self::from_model(NativeModel {
            tag: tag.to_string(),
            seq,
            mode,
            tensors,
            packed,
            layers,
            max_width,
            weights_from,
        }))
    }

    /// Build from generated init weights only — no filesystem access at
    /// all (not even a manifest probe).
    ///
    /// # Examples
    ///
    /// ```
    /// use simnet::features::NUM_FEATURES;
    /// use simnet::predictor::{LatencyPredictor, NativePredictor};
    /// use simnet::runtime::HEAD_OUT;
    ///
    /// let mut p = NativePredictor::from_init("fc2", 8)?;
    /// assert_eq!(p.seq_len(), 8);
    /// let inputs = vec![0.25f32; 2 * 8 * NUM_FEATURES];
    /// let mut raw = Vec::new();
    /// p.forward_raw(&inputs, 2, &mut raw)?;
    /// assert_eq!(raw.len(), 2 * HEAD_OUT);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn from_init(tag: &str, seq: usize) -> Result<Self> {
        let arch = Arch::parse(&export_name(tag))?;
        let tensors = init_tensors(arch, seq, tag);
        let (layers, max_width) =
            plan(arch, seq, &tensors).with_context(|| format!("native model {tag}"))?;
        let packed = pack_weights(&tensors);
        Ok(Self::from_model(NativeModel {
            tag: tag.to_string(),
            seq,
            mode: OutputMode::Hybrid,
            tensors,
            packed,
            layers,
            max_width,
            weights_from: "init(generated)".to_string(),
        }))
    }

    fn from_model(model: NativeModel) -> Self {
        NativePredictor {
            model: Arc::new(model),
            prev: Vec::new(),
            next: Vec::new(),
            tmp: Vec::new(),
            head: Vec::new(),
            served: 0,
        }
    }

    /// A cheap per-thread handle: shares the read-only weight arena and
    /// layer plan, with fresh (empty) scratch buffers and an independent
    /// `served` counter.
    pub fn clone_lite(&self) -> NativePredictor {
        NativePredictor {
            model: Arc::clone(&self.model),
            prev: Vec::new(),
            next: Vec::new(),
            tmp: Vec::new(),
            head: Vec::new(),
            served: 0,
        }
    }

    /// Whether two handles share one weight arena (i.e. one came from the
    /// other's [`clone_lite`](Self::clone_lite)).
    pub fn shares_weights_with(&self, other: &NativePredictor) -> bool {
        Arc::ptr_eq(&self.model, &other.model)
    }

    /// Model tag this predictor was loaded as.
    pub fn tag(&self) -> &str {
        &self.model.tag
    }

    /// Where the weights came from (`.smw` path or `init(generated)`).
    pub fn weights_from(&self) -> &str {
        &self.model.weights_from
    }

    /// Total learned parameters.
    pub fn param_count(&self) -> usize {
        self.model.tensors.iter().map(|t| t.len()).sum()
    }

    /// Run the forward pass over `n` encoded inputs packed in `inputs`
    /// (length >= `n * seq_len * NUM_FEATURES`); appends `n` rows of
    /// [`HEAD_OUT`] raw head floats to `out`.
    pub fn forward_raw(&mut self, inputs: &[f32], n: usize, out: &mut Vec<f32>) -> Result<()> {
        let width = self.model.seq * NUM_FEATURES;
        if inputs.len() < n * width {
            bail!("native forward: {} floats < {n} inputs x width {width}", inputs.len());
        }
        if n == 0 {
            return Ok(());
        }
        let cap = n * self.model.max_width;
        if self.prev.len() < cap {
            self.prev.resize(cap, 0.0);
        }
        if self.next.len() < cap {
            self.next.resize(cap, 0.0);
        }
        if self.tmp.len() < cap {
            self.tmp.resize(cap, 0.0);
        }
        let mut prev = std::mem::take(&mut self.prev);
        let mut next = std::mem::take(&mut self.next);
        let model = &self.model;
        let mut first = true;
        for layer in &model.layers {
            {
                let src: &[f32] = if first { &inputs[..n * width] } else { &prev };
                apply_layer(model, layer, src, &mut next, &mut self.tmp, n);
            }
            std::mem::swap(&mut prev, &mut next);
            first = false;
        }
        out.extend_from_slice(&prev[..n * HEAD_OUT]);
        self.prev = prev;
        self.next = next;
        Ok(())
    }
}

/// Execute one plan step: `src` holds the previous activations (or the
/// encoded inputs), `dst` receives this layer's output.
fn apply_layer(
    model: &NativeModel,
    layer: &Layer,
    src: &[f32],
    dst: &mut [f32],
    tmp: &mut [f32],
    n: usize,
) {
    let t = |i: usize| model.tensors[i].data.as_slice();
    let pm = |i: usize| model.packed[i].as_ref().expect("2-D tensor must be packed");
    match *layer {
        Layer::Dense { w, b, relu } => {
            kernels::dense_auto(src, t(w), pm(w), t(b), dst, n, relu);
        }
        Layer::Conv { w, b, pairs } => {
            kernels::dense_auto(src, t(w), pm(w), t(b), dst, n * pairs, true);
        }
        Layer::Residual { w1, b1, w2, b2, rows, c } => {
            let r = n * rows;
            kernels::dense_auto(src, t(w1), pm(w1), t(b1), tmp, r, true);
            kernels::dense_auto(tmp, t(w2), pm(w2), t(b2), dst, r, false);
            for (yo, &xi) in dst[..r * c].iter_mut().zip(&src[..r * c]) {
                *yo = fastmath::relu(*yo + xi);
            }
        }
    }
}

impl LatencyPredictor for NativePredictor {
    fn seq_len(&self) -> usize {
        self.model.seq
    }

    fn predict(&mut self, inputs: &[f32], n: usize) -> Result<Vec<(u32, u32, u32)>> {
        let mut head = std::mem::take(&mut self.head);
        head.clear();
        self.forward_raw(inputs, n, &mut head)?;
        let mode = self.model.mode;
        let out = head.chunks_exact(HEAD_OUT).take(n).map(|row| decode_row(row, mode)).collect();
        self.head = head;
        self.served += n as u64;
        Ok(out)
    }

    fn served(&self) -> u64 {
        self.served
    }

    /// Forked handles share the weight arena via [`clone_lite`]
    /// (a few KB of fresh scratch each), so the engine runs one per
    /// encode worker instead of serializing on this handle.
    ///
    /// [`clone_lite`]: NativePredictor::clone_lite
    fn fork(&self) -> Option<Box<dyn LatencyPredictor>> {
        Some(Box::new(self.clone_lite()))
    }

    fn absorb_served(&mut self, n: u64) {
        self.served += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_specs_match_python_shapes() {
        // Spot-check against python compile.model.param_specs at seq 32.
        let fc3 = param_specs(Arch::Fc3, 32);
        assert_eq!(fc3[0], ("fc0/w".to_string(), vec![1600, 512]));
        assert_eq!(fc3.last().unwrap(), &("out/b".to_string(), vec![HEAD_OUT]));
        let c3 = param_specs(Arch::C3, 32);
        assert_eq!(c3[0], ("conv0/w".to_string(), vec![100, 64]));
        assert_eq!(c3[4], ("conv2/w".to_string(), vec![192, 128]));
        // After 3 halvings: 128 channels * 4 positions.
        assert_eq!(c3[6], ("fc0/w".to_string(), vec![512, 256]));
        let rb = param_specs(Arch::Rb, 32);
        assert_eq!(rb[2], ("res0/w1".to_string(), vec![64, 64]));
        assert_eq!(rb.len(), 3 * 6 + 4);
    }

    #[test]
    fn init_weights_are_tag_deterministic() {
        let a = init_tensors(Arch::Fc2, 8, "fc2");
        let b = init_tensors(Arch::Fc2, 8, "fc2");
        let c = init_tensors(Arch::Fc2, 8, "fc2_other");
        assert_eq!(a, b);
        assert_ne!(a[0].data, c[0].data, "different tags must seed different weights");
        assert!(a[1].data.iter().all(|&v| v == 0.0), "biases start at zero");
        let limit = (6.0 / (8.0 * NUM_FEATURES as f32 + 256.0)).sqrt();
        assert!(a[0].data.iter().all(|&v| v.abs() <= limit));
        assert!(a[0].data.iter().any(|&v| v < 0.0) && a[0].data.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn forward_shapes_and_reuse() {
        let mut p = NativePredictor::from_init("c3", 8).unwrap();
        assert_eq!(p.seq_len(), 8);
        let width = 8 * NUM_FEATURES;
        let inputs: Vec<f32> = (0..3 * width).map(|i| ((i % 13) as f32) / 13.0).collect();
        let mut raw = Vec::new();
        p.forward_raw(&inputs, 3, &mut raw).unwrap();
        assert_eq!(raw.len(), 3 * HEAD_OUT);
        // Batched forward == row-at-a-time forward (buffer reuse must not
        // leak state across calls).
        for (i, row) in raw.chunks_exact(HEAD_OUT).enumerate() {
            let mut one = Vec::new();
            p.forward_raw(&inputs[i * width..(i + 1) * width], 1, &mut one).unwrap();
            assert_eq!(one, row, "row {i}");
        }
        let triples = p.predict(&inputs, 3).unwrap();
        assert_eq!(triples.len(), 3);
        assert_eq!(p.served(), 3);
    }

    #[test]
    fn seq_len_one_fc_model_works() {
        // Kernel edge shape: seq_len 1 makes the first dense a 50-wide
        // input, and the 33-wide head is never a multiple of the block.
        let mut p = NativePredictor::from_init("fc2", 1).unwrap();
        assert_eq!(p.seq_len(), 1);
        let inputs: Vec<f32> = (0..2 * NUM_FEATURES).map(|i| ((i % 7) as f32) / 7.0).collect();
        let mut raw = Vec::new();
        p.forward_raw(&inputs, 2, &mut raw).unwrap();
        assert_eq!(raw.len(), 2 * HEAD_OUT);
        let triples = p.predict(&inputs, 2).unwrap();
        assert_eq!(triples.len(), 2);
    }

    #[test]
    fn clone_after_large_batch_sizes_buffers_from_plan() {
        // Regression guard: per-handle scratch must be sized from the
        // compiled plan's max_width per call, never inherited from the
        // parent's batch history. A small-batch clone taken after the
        // parent ran a large batch (and a later large batch on that
        // clone) must match fresh-handle results exactly.
        let parent = {
            let mut p = NativePredictor::from_init("c3", 8).unwrap();
            let width = 8 * NUM_FEATURES;
            let big: Vec<f32> = (0..64 * width).map(|i| ((i % 11) as f32) / 11.0).collect();
            let mut raw = Vec::new();
            p.forward_raw(&big, 64, &mut raw).unwrap();
            p
        };
        let mut clone = parent.clone_lite();
        let mut fresh = NativePredictor::from_init("c3", 8).unwrap();
        let width = 8 * NUM_FEATURES;
        let small: Vec<f32> = (0..width).map(|i| ((i % 5) as f32) / 5.0).collect();
        let big: Vec<f32> = (0..32 * width).map(|i| ((i % 9) as f32) / 9.0).collect();
        for (inputs, n) in [(&small, 1usize), (&big, 32), (&small, 1)] {
            let mut a = Vec::new();
            let mut b = Vec::new();
            clone.forward_raw(inputs, n, &mut a).unwrap();
            fresh.forward_raw(inputs, n, &mut b).unwrap();
            assert_eq!(a, b, "clone vs fresh at n={n}");
        }
        assert_eq!(clone.served(), 0, "clone_lite starts a fresh served counter");
    }

    #[test]
    fn fork_shares_arena_and_absorbs_served() {
        let mut p = NativePredictor::from_init("fc2", 4).unwrap();
        let width = 4 * NUM_FEATURES;
        let inputs: Vec<f32> = (0..3 * width).map(|i| ((i % 13) as f32) / 13.0).collect();
        let want = p.predict(&inputs, 3).unwrap();
        let mut forked = p.fork().expect("native predictor must fork");
        assert_eq!(forked.seq_len(), p.seq_len());
        let got = forked.predict(&inputs, 3).unwrap();
        assert_eq!(got, want, "forked handle must agree exactly");
        assert_eq!(forked.served(), 3);
        assert_eq!(p.served(), 3, "fork does not absorb back automatically");
        p.absorb_served(forked.served());
        assert_eq!(p.served(), 6);
    }

    #[test]
    fn unsupported_arch_is_a_clear_error() {
        let err = NativePredictor::from_init("lstm2", 8).unwrap_err();
        assert!(err.to_string().contains("PJRT"), "err: {err}");
        let err = Arch::parse("tx2").unwrap_err();
        assert!(err.to_string().contains("tx2"), "err: {err}");
    }

    #[test]
    fn seq_not_divisible_for_conv_stack_errors() {
        let err = NativePredictor::from_init("c3", 6).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("divisible"), "err: {msg}");
    }

    #[test]
    fn plan_rejects_malformed_tensor_sets() {
        let mut tensors = init_tensors(Arch::Fc2, 4, "fc2");
        tensors.swap(0, 1);
        assert!(plan(Arch::Fc2, 4, &tensors).is_err(), "order violation must fail");
        let mut tensors = init_tensors(Arch::Fc2, 4, "fc2");
        tensors.push(Tensor::new("extra", vec![1], vec![0.0]));
        let err = plan(Arch::Fc2, 4, &tensors).unwrap_err();
        assert!(err.to_string().contains("extra"), "err: {err}");
        let tensors = init_tensors(Arch::Fc2, 8, "fc2");
        assert!(plan(Arch::Fc2, 4, &tensors).is_err(), "seq mismatch must fail shape chain");
    }
}
