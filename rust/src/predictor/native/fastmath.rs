//! Fast activation helpers for the native inference hot loop.
//!
//! `f32::max(0.0)` lowers to a single `maxss`/`fmaxnm` instruction (no
//! branch, no NaN-propagation library call), which matters because the
//! forward pass applies it to every hidden activation of every batch.

/// Branchless ReLU.
#[inline(always)]
pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

/// ReLU applied in place over a whole activation row.
#[inline]
pub fn relu_inplace(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = v.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_negatives_only() {
        assert_eq!(relu(-3.5), 0.0);
        assert_eq!(relu(0.0), 0.0);
        assert_eq!(relu(2.25), 2.25);
        let mut xs = [-1.0, 0.5, -0.0, 7.0];
        relu_inplace(&mut xs);
        assert_eq!(xs, [0.0, 0.5, 0.0, 7.0]);
    }
}
