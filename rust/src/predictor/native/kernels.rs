//! The one micro-kernel behind the native backend: batched dense
//! (`y = act(x @ w + b)`) over preallocated buffers.
//!
//! Every layer of the supported model zoo lowers to it (mirroring the
//! Pallas story on the python side, where `conv1d_k2s2` is a reshape +
//! matmul): a k2s2 convolution is a dense over `L/2` position-pair rows,
//! and a residual block is two dense calls plus a fused skip-add.
//!
//! Layout: `x` row-major `(rows, d_in)`, `w` row-major `(d_in, d_out)`,
//! `y` row-major `(rows, d_out)`. The inner loop is an axpy over `w`'s
//! rows, so the weight matrix streams sequentially and the compiler can
//! vectorize the `d_out` dimension; input zeros (post-ReLU activations
//! and zero-padded context slots are mostly zero) skip their whole axpy.

use super::fastmath;

/// Compute `y[r] = act(x[r] @ w + b)` for the first `rows` rows.
///
/// `d_out` is `bias.len()` and `d_in` is `w.len() / d_out`; `x` and `y`
/// may be longer than `rows * d` (grow-only scratch buffers), the excess
/// is ignored.
pub fn dense_batch(x: &[f32], w: &[f32], bias: &[f32], y: &mut [f32], rows: usize, relu: bool) {
    let d_out = bias.len();
    let d_in = w.len() / d_out;
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert!(x.len() >= rows * d_in);
    debug_assert!(y.len() >= rows * d_out);
    for (xr, yr) in x.chunks_exact(d_in).zip(y.chunks_exact_mut(d_out)).take(rows) {
        yr.copy_from_slice(bias);
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for (yo, &wv) in yr.iter_mut().zip(wrow) {
                *yo += xi * wv;
            }
        }
        if relu {
            fastmath::relu_inplace(yr);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_hand_matmul() {
        // x (2,3) @ w (3,2) + b, no relu.
        let x = [1.0, 2.0, 3.0, -1.0, 0.0, 0.5];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = [10.0, -10.0];
        let mut y = [0.0f32; 4];
        dense_batch(&x, &w, &b, &mut y, 2, false);
        assert_eq!(y, [14.0, -5.0, 9.5, -9.5]);
        dense_batch(&x, &w, &b, &mut y, 2, true);
        assert_eq!(y, [14.0, 0.0, 9.5, 0.0]);
    }

    #[test]
    fn zero_skip_is_exact() {
        // The xi == 0.0 fast path must not change results: compare a row
        // with zeros against the same row with zeros contributed by a
        // zero weight column instead.
        let w = [0.5, -0.25, 1.5, 2.0];
        let b = [0.125, 0.25];
        let dense = |x: &[f32]| {
            let mut y = [0.0f32; 2];
            dense_batch(x, &w, &b, &mut y, 1, false);
            y
        };
        assert_eq!(dense(&[0.0, 3.0]), dense(&[-0.0, 3.0]));
        assert_eq!(dense(&[0.0, 3.0]), [0.125 + 4.5, 0.25 + 6.0]);
    }

    #[test]
    fn oversized_buffers_are_ignored() {
        let x = [2.0, 1.0, 99.0, 99.0]; // one real row + garbage tail
        let w = [1.0, 3.0];
        let b = [1.0];
        let mut y = [7.0f32; 3];
        dense_batch(&x, &w, &b, &mut y, 1, false);
        assert_eq!(y, [6.0, 7.0, 7.0]);
    }
}
