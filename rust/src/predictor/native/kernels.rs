//! Batched dense micro-kernels behind the native backend:
//! `y = act(x @ w + b)` over preallocated buffers, in two shapes.
//!
//! Every layer of the supported model zoo lowers to a dense (mirroring
//! the Pallas story on the python side, where `conv1d_k2s2` is a reshape
//! + matmul): a k2s2 convolution is a dense over `L/2` position-pair
//! rows, and a residual block is two dense calls plus a fused skip-add.
//!
//! Two kernels implement it:
//!
//! * [`dense_batch`] — the scalar zero-skip reference path. The inner
//!   loop is an axpy over `w`'s rows; input zeros (zero-padded context
//!   slots, post-ReLU activations) skip their whole axpy. Fastest when
//!   the input is mostly zeros, and the semantics every other kernel
//!   must reproduce exactly.
//! * [`dense_blocked`] — the cache-blocked register-tile path over a
//!   [`PackedMat`]: [`MR`]×[`NR`] f32 accumulator tiles initialized from
//!   the bias, streaming one contiguous weight panel at a time. The
//!   fixed-width [`NR`]-lane inner update autovectorizes on stable
//!   toolchains; the `portable-simd` cargo feature swaps in an explicit
//!   `std::simd::f32x8` form (nightly) with the same operation order.
//! * [`dense_auto`] — the production dispatch: per group of [`MR`] rows,
//!   routes to the zero-skip path when the group is sparse enough and to
//!   the blocked tiles otherwise.
//!
//! Bit-compatibility contract: for every output element, both kernels
//! evaluate `bias + Σ x[i] * w[i]` in ascending-`i` order with separate
//! f32 multiply and add (no FMA, no split accumulators), so results are
//! `==`-identical per row. The only representable difference is the sign
//! of a zero (the zero-skip path may keep `-0.0` where the blocked path
//! adds `+0.0` over it, and vice versa), which `==`, the decode path,
//! and the golden fixtures are all insensitive to. The randomized
//! equivalence tests below pin this on every edge shape the model zoo
//! produces (33-wide head, seq-len-1 inputs, non-multiple-of-block
//! dims).
//!
//! Layout: `x` row-major `(rows, d_in)`, `w` row-major `(d_in, d_out)`,
//! `y` row-major `(rows, d_out)`; `x`/`y` may be longer than `rows * d`
//! (grow-only scratch buffers) — the excess is ignored.

use super::fastmath;

/// Output-column lanes per weight panel (the register-tile width).
pub const NR: usize = 8;

/// Input rows per register tile: [`MR`] independent accumulation chains
/// keep the FP pipeline full without touching memory for `y`.
pub const MR: usize = 4;

/// Route a row group to the zero-skip scalar path when fewer than
/// 1/`SPARSE_DENSITY_DIV` of its inputs are nonzero: below ~25% density
/// the skipped axpys beat the blocked tiles' wasted multiply-by-zero
/// lanes, above it the contiguous panel streaming wins.
const SPARSE_DENSITY_DIV: usize = 4;

/// A weight matrix repacked at plan-compile time into blocked row-panel
/// layout for [`dense_blocked`]: `ceil(d_out / NR)` panels of
/// `d_in * NR` floats, where panel `p` holds output columns
/// `p*NR .. p*NR + NR` (zero-padded past `d_out`) laid out row-major by
/// input index — `panel[i * NR + j]` is `w[i * d_out + p*NR + j]`. The
/// inner loop therefore streams one contiguous panel front to back.
pub struct PackedMat {
    d_in: usize,
    d_out: usize,
    data: Vec<f32>,
}

impl PackedMat {
    /// Repack row-major `w` of shape `(d_in, d_out)`.
    pub fn pack(w: &[f32], d_in: usize, d_out: usize) -> PackedMat {
        assert_eq!(w.len(), d_in * d_out, "pack: weight length vs shape ({d_in}, {d_out})");
        let panels = d_out.div_ceil(NR);
        let mut data = vec![0.0f32; panels * d_in * NR];
        for (p, panel) in data.chunks_exact_mut(d_in * NR).enumerate() {
            let c0 = p * NR;
            let width = NR.min(d_out - c0);
            for i in 0..d_in {
                panel[i * NR..i * NR + width]
                    .copy_from_slice(&w[i * d_out + c0..i * d_out + c0 + width]);
            }
        }
        PackedMat { d_in, d_out, data }
    }

    /// Input width of the packed matrix.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output width of the packed matrix.
    pub fn d_out(&self) -> usize {
        self.d_out
    }
}

/// One [`NR`]-wide accumulator register row. Both implementations
/// evaluate `acc[j] + x * w[j]` with a separate f32 multiply and add (no
/// `mul_add` — FMA would round differently from the scalar reference
/// path), so the stable and `portable-simd` builds are bit-identical.
#[cfg(not(feature = "portable-simd"))]
#[derive(Clone, Copy)]
struct Acc([f32; NR]);

#[cfg(not(feature = "portable-simd"))]
impl Acc {
    #[inline(always)]
    fn load(v: [f32; NR]) -> Acc {
        Acc(v)
    }

    /// `acc += x * w`, lane-wise — a fixed-width loop over two arrays,
    /// which LLVM autovectorizes on stable toolchains.
    #[inline(always)]
    fn madd(&mut self, x: f32, w: &[f32; NR]) {
        for (a, &wv) in self.0.iter_mut().zip(w) {
            *a += x * wv;
        }
    }

    #[inline(always)]
    fn to_array(self) -> [f32; NR] {
        self.0
    }
}

#[cfg(feature = "portable-simd")]
#[derive(Clone, Copy)]
struct Acc(std::simd::f32x8);

#[cfg(feature = "portable-simd")]
impl Acc {
    #[inline(always)]
    fn load(v: [f32; NR]) -> Acc {
        Acc(std::simd::f32x8::from_array(v))
    }

    #[inline(always)]
    fn madd(&mut self, x: f32, w: &[f32; NR]) {
        // Multiply then add, NOT mul_add: keeps rounding identical to
        // the scalar kernels.
        self.0 += std::simd::f32x8::splat(x) * std::simd::f32x8::from_array(*w);
    }

    #[inline(always)]
    fn to_array(self) -> [f32; NR] {
        self.0.to_array()
    }
}

// The explicit-SIMD accumulator is hardwired to 8 lanes.
#[cfg(feature = "portable-simd")]
const _: () = assert!(NR == 8);

/// Compute `y[r] = act(x[r] @ w + b)` for the first `rows` rows — the
/// scalar zero-skip kernel (see the module docs for the layout and the
/// bit-compatibility contract).
///
/// `d_out` is `bias.len()` and `d_in` is `w.len() / d_out`.
pub fn dense_batch(x: &[f32], w: &[f32], bias: &[f32], y: &mut [f32], rows: usize, relu: bool) {
    let d_out = bias.len();
    let d_in = w.len() / d_out;
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert!(x.len() >= rows * d_in);
    debug_assert!(y.len() >= rows * d_out);
    for (xr, yr) in x.chunks_exact(d_in).zip(y.chunks_exact_mut(d_out)).take(rows) {
        yr.copy_from_slice(bias);
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for (yo, &wv) in yr.iter_mut().zip(wrow) {
                *yo += xi * wv;
            }
        }
        if relu {
            fastmath::relu_inplace(yr);
        }
    }
}

/// One `M`×[`NR`] register tile: `M` consecutive input rows against every
/// weight panel. `x` and `y` are the tile's row-0 suffixes of the batch
/// buffers (at least `M * d_in` / `M * d_out` floats long).
#[inline]
fn dense_tile<const M: usize>(x: &[f32], pm: &PackedMat, bias: &[f32], y: &mut [f32], relu: bool) {
    let (d_in, d_out) = (pm.d_in, pm.d_out);
    let mut c0 = 0;
    for panel in pm.data.chunks_exact(d_in * NR) {
        let width = NR.min(d_out - c0);
        // Accumulators start at the bias, exactly like the scalar path's
        // `copy_from_slice(bias)`. Padding lanes start at 0 and only ever
        // accumulate `x * 0.0` from the zero-padded panel tail; they are
        // never copied out.
        let mut init = [0.0f32; NR];
        init[..width].copy_from_slice(&bias[c0..c0 + width]);
        let mut acc = [Acc::load(init); M];
        for (i, wrow) in panel.chunks_exact(NR).enumerate() {
            let wrow: &[f32; NR] = wrow.try_into().unwrap();
            for (r, a) in acc.iter_mut().enumerate() {
                a.madd(x[r * d_in + i], wrow);
            }
        }
        for (r, a) in acc.iter().enumerate() {
            let vals = a.to_array();
            let out = &mut y[r * d_out + c0..r * d_out + c0 + width];
            for (yo, &v) in out.iter_mut().zip(&vals[..width]) {
                *yo = if relu { fastmath::relu(v) } else { v };
            }
        }
        c0 += NR;
    }
}

/// The cache-blocked kernel: [`dense_batch`]'s contract over a
/// [`PackedMat`], full [`MR`]-row tiles first, then single-row tiles for
/// the remainder. `==`-identical to [`dense_batch`] per row.
pub fn dense_blocked(
    x: &[f32],
    pm: &PackedMat,
    bias: &[f32],
    y: &mut [f32],
    rows: usize,
    relu: bool,
) {
    let (d_in, d_out) = (pm.d_in, pm.d_out);
    debug_assert_eq!(bias.len(), d_out);
    debug_assert!(x.len() >= rows * d_in);
    debug_assert!(y.len() >= rows * d_out);
    let mut r = 0;
    while r + MR <= rows {
        dense_tile::<MR>(&x[r * d_in..], pm, bias, &mut y[r * d_out..], relu);
        r += MR;
    }
    while r < rows {
        dense_tile::<1>(&x[r * d_in..], pm, bias, &mut y[r * d_out..], relu);
        r += 1;
    }
}

/// Density-dispatching dense: per group of up to [`MR`] consecutive
/// rows, count the group's nonzeros and route it to the zero-skip scalar
/// path (sparse pre-filter) or to the blocked tiles. Because both paths
/// are `==`-identical per row, the grouping can never change a result —
/// only how fast it is computed.
pub fn dense_auto(
    x: &[f32],
    w: &[f32],
    pm: &PackedMat,
    bias: &[f32],
    y: &mut [f32],
    rows: usize,
    relu: bool,
) {
    let (d_in, d_out) = (pm.d_in, pm.d_out);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    let mut r = 0;
    while r < rows {
        let m = MR.min(rows - r);
        let xg = &x[r * d_in..r * d_in + m * d_in];
        let nnz = xg.iter().filter(|&&v| v != 0.0).count();
        if nnz * SPARSE_DENSITY_DIV < xg.len() {
            dense_batch(xg, w, bias, &mut y[r * d_out..(r + m) * d_out], m, relu);
        } else if m == MR {
            dense_tile::<MR>(xg, pm, bias, &mut y[r * d_out..], relu);
        } else {
            for k in 0..m {
                dense_tile::<1>(&x[(r + k) * d_in..], pm, bias, &mut y[(r + k) * d_out..], relu);
            }
        }
        r += m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// xorshift64* step — the same generator `native::mod` seeds init
    /// weights with; tests roll their own RNG because no rand crate is
    /// vendored.
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    /// Uniform in [-1, 1), zeroed with probability `zero_pct`/100.
    fn rand_val(state: &mut u64, zero_pct: u64) -> f32 {
        if xorshift(state) % 100 < zero_pct {
            return 0.0;
        }
        let x = xorshift(state);
        ((x >> 40) as f32) / (1u64 << 23) as f32 - 1.0
    }

    fn rand_vec(state: &mut u64, len: usize, zero_pct: u64) -> Vec<f32> {
        (0..len).map(|_| rand_val(state, zero_pct)).collect()
    }

    #[test]
    fn dense_matches_hand_matmul() {
        // x (2,3) @ w (3,2) + b, no relu.
        let x = [1.0, 2.0, 3.0, -1.0, 0.0, 0.5];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = [10.0, -10.0];
        let mut y = [0.0f32; 4];
        dense_batch(&x, &w, &b, &mut y, 2, false);
        assert_eq!(y, [14.0, -5.0, 9.5, -9.5]);
        dense_batch(&x, &w, &b, &mut y, 2, true);
        assert_eq!(y, [14.0, 0.0, 9.5, 0.0]);
        // Same result through the blocked and dispatching kernels.
        let pm = PackedMat::pack(&w, 3, 2);
        let mut yb = [0.0f32; 4];
        dense_blocked(&x, &pm, &b, &mut yb, 2, false);
        assert_eq!(yb, [14.0, -5.0, 9.5, -9.5]);
        dense_auto(&x, &w, &pm, &b, &mut yb, 2, true);
        assert_eq!(yb, [14.0, 0.0, 9.5, 0.0]);
    }

    #[test]
    fn zero_skip_is_exact() {
        // The xi == 0.0 fast path must not change results: compare a row
        // with zeros against the same row with zeros contributed by a
        // zero weight column instead.
        let w = [0.5, -0.25, 1.5, 2.0];
        let b = [0.125, 0.25];
        let dense = |x: &[f32]| {
            let mut y = [0.0f32; 2];
            dense_batch(x, &w, &b, &mut y, 1, false);
            y
        };
        assert_eq!(dense(&[0.0, 3.0]), dense(&[-0.0, 3.0]));
        assert_eq!(dense(&[0.0, 3.0]), [0.125 + 4.5, 0.25 + 6.0]);
    }

    #[test]
    fn oversized_buffers_are_ignored() {
        let x = [2.0, 1.0, 99.0, 99.0]; // one real row + garbage tail
        let w = [1.0, 3.0];
        let b = [1.0];
        let mut y = [7.0f32; 3];
        dense_batch(&x, &w, &b, &mut y, 1, false);
        assert_eq!(y, [6.0, 7.0, 7.0]);
        let pm = PackedMat::pack(&w, 2, 1);
        let mut y = [7.0f32; 3];
        dense_blocked(&x, &pm, &b, &mut y, 1, false);
        assert_eq!(y, [6.0, 7.0, 7.0]);
    }

    #[test]
    fn pack_layout_is_panel_major_with_zero_padded_tail() {
        // w (2, 10): two panels — a full 8-wide one and a 2-wide tail.
        let d_in = 2;
        let d_out = 10;
        let w: Vec<f32> = (0..d_in * d_out).map(|i| i as f32).collect();
        let pm = PackedMat::pack(&w, d_in, d_out);
        assert_eq!(pm.d_in(), d_in);
        assert_eq!(pm.d_out(), d_out);
        assert_eq!(pm.data.len(), 2 * d_in * NR);
        for i in 0..d_in {
            for j in 0..NR {
                assert_eq!(pm.data[i * NR + j], w[i * d_out + j], "panel 0 [{i}][{j}]");
            }
            for j in 0..2 {
                assert_eq!(
                    pm.data[d_in * NR + i * NR + j],
                    w[i * d_out + NR + j],
                    "panel 1 [{i}][{j}]"
                );
            }
            for j in 2..NR {
                assert_eq!(pm.data[d_in * NR + i * NR + j], 0.0, "panel 1 padding [{i}][{j}]");
            }
        }
    }

    /// Satellite coverage: every non-multiple-of-block edge the model zoo
    /// produces — the 33-wide head, seq-len-1-style single-row batches,
    /// 1-wide inputs/outputs, exact-block shapes — must agree with the
    /// scalar reference exactly (`assert_eq!`, not a tolerance).
    #[test]
    fn blocked_matches_scalar_on_edge_shapes() {
        let mut state = 0x1234_5678_9abc_def0u64;
        let shapes =
            [(1, 1), (1, 33), (33, 1), (2, 8), (3, 33), (7, 9), (9, 16), (50, 33), (16, 64)];
        for (d_in, d_out) in shapes {
            for rows in [1usize, 2, 3, 4, 5, 7, 9] {
                let x = rand_vec(&mut state, rows * d_in, 40);
                let w = rand_vec(&mut state, d_in * d_out, 0);
                let b = rand_vec(&mut state, d_out, 0);
                let pm = PackedMat::pack(&w, d_in, d_out);
                for relu in [false, true] {
                    let mut ys = vec![0.0f32; rows * d_out];
                    let mut yb = vec![0.0f32; rows * d_out];
                    let mut ya = vec![0.0f32; rows * d_out];
                    dense_batch(&x, &w, &b, &mut ys, rows, relu);
                    dense_blocked(&x, &pm, &b, &mut yb, rows, relu);
                    dense_auto(&x, &w, &pm, &b, &mut ya, rows, relu);
                    assert_eq!(ys, yb, "blocked ({d_in},{d_out}) rows={rows} relu={relu}");
                    assert_eq!(ys, ya, "auto ({d_in},{d_out}) rows={rows} relu={relu}");
                }
            }
        }
    }

    /// Satellite coverage: the sparse pre-filter and the dense tiles must
    /// agree whichever way [`dense_auto`] routes a group — pinned at both
    /// density extremes (95% zeros routes sparse, fully dense routes
    /// blocked) and at a mixed batch where different groups take
    /// different paths.
    #[test]
    fn sparse_and_dense_routes_agree() {
        let mut state = 0xfeed_f00d_dead_beefu64;
        let (d_in, d_out, rows) = (40, 24, 9);
        let w = rand_vec(&mut state, d_in * d_out, 0);
        let b = rand_vec(&mut state, d_out, 0);
        let pm = PackedMat::pack(&w, d_in, d_out);
        for zero_pct in [95u64, 0] {
            let x = rand_vec(&mut state, rows * d_in, zero_pct);
            let mut ys = vec![0.0f32; rows * d_out];
            let mut ya = vec![0.0f32; rows * d_out];
            dense_batch(&x, &w, &b, &mut ys, rows, true);
            dense_auto(&x, &w, &pm, &b, &mut ya, rows, true);
            assert_eq!(ys, ya, "zero_pct={zero_pct}");
        }
        // Mixed: first MR-row group all zeros (sparse route), second
        // fully dense (blocked route), ragged 1-row tail.
        let mut x = rand_vec(&mut state, rows * d_in, 0);
        for v in x.iter_mut().take(MR * d_in) {
            *v = 0.0;
        }
        let mut ys = vec![0.0f32; rows * d_out];
        let mut ya = vec![0.0f32; rows * d_out];
        dense_batch(&x, &w, &b, &mut ys, rows, false);
        dense_auto(&x, &w, &pm, &b, &mut ya, rows, false);
        assert_eq!(ys, ya);
    }

    /// Satellite coverage: proptest-style randomized scalar-vs-blocked
    /// equivalence over a seeded xorshift stream of shapes, densities,
    /// and activations (no proptest crate is vendored — the case
    /// generator is the deterministic RNG above, so failures reproduce).
    #[test]
    fn randomized_scalar_vs_blocked_equivalence() {
        let mut state = 0x0dd_ba11_0f_c0ffeeu64;
        for case in 0..200 {
            let d_in = 1 + (xorshift(&mut state) % 64) as usize;
            let d_out = 1 + (xorshift(&mut state) % 64) as usize;
            let rows = 1 + (xorshift(&mut state) % 8) as usize;
            let zero_pct = xorshift(&mut state) % 100;
            let relu = xorshift(&mut state) % 2 == 0;
            let x = rand_vec(&mut state, rows * d_in, zero_pct);
            let w = rand_vec(&mut state, d_in * d_out, 0);
            let b = rand_vec(&mut state, d_out, 0);
            let pm = PackedMat::pack(&w, d_in, d_out);
            let mut ys = vec![0.0f32; rows * d_out];
            let mut yb = vec![0.0f32; rows * d_out];
            let mut ya = vec![0.0f32; rows * d_out];
            dense_batch(&x, &w, &b, &mut ys, rows, relu);
            dense_blocked(&x, &pm, &b, &mut yb, rows, relu);
            dense_auto(&x, &w, &pm, &b, &mut ya, rows, relu);
            assert_eq!(ys, yb, "case {case}: ({d_in},{d_out}) rows={rows} zero%={zero_pct}");
            assert_eq!(ys, ya, "case {case}: ({d_in},{d_out}) rows={rows} zero%={zero_pct}");
        }
    }
}
