//! Latency-predictor abstraction: the green box of paper Figure 1.
//!
//! [`LatencyPredictor`] is what the coordinator's simulation loops talk
//! to. Three implementations back it: [`MlPredictor`] (the AOT-compiled
//! PJRT path), [`native::NativePredictor`] (the pure-Rust in-process
//! forward pass over the same `.smw` weights — no runtime dependency),
//! and [`TablePredictor`], a deterministic analytical stand-in used by
//! tests and benches that must run without artifacts (it also doubles as
//! the "simple analytical model" baseline in ablation benches).
//!
//! [`WeightsSource`] is the shared answer to "where do the weights come
//! from" for both ML backends, so the explicit-path / trained / init
//! resolution rules (and their error behavior) cannot drift apart.

pub mod native;

use std::path::{Path, PathBuf};

use anyhow::Result;

pub use native::NativePredictor;

use crate::features::{self, ContextMode, NUM_FEATURES};
use crate::runtime::{decode_row, ModelBank, HEAD_OUT};

/// Where a predictor's weights come from — shared by the PJRT backend
/// (`PredictorSpec::Ml`) and the native backend (`PredictorSpec::Native`)
/// so both resolve weights with identical rules.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WeightsSource {
    /// Resolve automatically: the trained `<tag>.smw` if present, else the
    /// base architecture's `<base>.smw` / `<base>.init.smw`, else (native
    /// backend only) deterministic generated init weights.
    Auto,
    /// Explicit `.smw` path. A missing file is an error naming the path —
    /// never a silent fallback to init weights.
    Path(PathBuf),
    /// Force init weights: `<base>.init.smw` for the PJRT backend,
    /// in-process generated weights for the native backend.
    Init,
}

/// Map a trained model *tag* to the architecture name its exported
/// artifacts are stored under: tags may carry suffixes (e.g. `c3_reg`,
/// `c3_big`) while sharing the export of their base architecture.
pub fn export_name(tag: &str) -> String {
    for base in ["ithemal_lstm2", "lstm2", "fc2", "fc3", "c1", "c3", "rb", "tx2"] {
        if tag == base || tag.starts_with(&format!("{base}_")) {
            return base.to_string();
        }
    }
    tag.to_string()
}

/// A batched fetch/execution/store latency predictor.
///
/// `Send` is a supertrait so predictors can sit behind the pipelined
/// [`crate::coordinator::BatchEngine`]. Today the engine calls `predict`
/// only from the coordinating thread inside its thread scope, so the
/// bound is not yet exercised — it is a forward guarantee for a dedicated
/// predict thread / multi-engine pools. The vendored `xla` stub types are
/// plain structs, so `MlPredictor` satisfies it automatically; when
/// swapping in the real PJRT bindings, keep the handle types `Send` or
/// wrap them.
pub trait LatencyPredictor: Send {
    /// Instruction slots per encoded input.
    fn seq_len(&self) -> usize;

    /// Predict latencies for `n` encoded inputs packed in `inputs`
    /// (`n * seq_len * NUM_FEATURES` floats). Returns one (F, E, S) triple
    /// per input.
    fn predict(&mut self, inputs: &[f32], n: usize) -> Result<Vec<(u32, u32, u32)>>;

    /// Total predictions served.
    fn served(&self) -> u64;

    /// How this predictor expects context instructions to be selected.
    fn context_mode(&self) -> ContextMode {
        ContextMode::SimNet
    }

    /// Hand out an independent handle over the same model, if this
    /// predictor supports it. Forked handles must predict exactly what
    /// the parent would (same weights, same decode), with their own
    /// scratch state and a zeroed `served` counter, so the engine can
    /// run one per encode worker without any cross-thread serialization.
    ///
    /// The default (`None`) keeps predictors single-handle; the engine
    /// then falls back to its shared-handle pipelined loop.
    fn fork(&self) -> Option<Box<dyn LatencyPredictor>> {
        None
    }

    /// Fold a forked handle's `served` count back into this handle, so
    /// totals reported by the parent equal the single-handle run.
    fn absorb_served(&mut self, _n: u64) {}
}

/// PJRT-backed predictor.
pub struct MlPredictor {
    bank: ModelBank,
    scratch: Vec<f32>,
}

impl MlPredictor {
    /// Load `model` from the artifacts directory (weights resolved as in
    /// [`ModelBank::load`]).
    pub fn load(artifacts: &Path, model: &str, weights: Option<&Path>) -> Result<Self> {
        Ok(MlPredictor { bank: ModelBank::load(artifacts, model, weights)?, scratch: Vec::new() })
    }

    pub fn bank(&self) -> &ModelBank {
        &self.bank
    }
}

impl LatencyPredictor for MlPredictor {
    fn seq_len(&self) -> usize {
        self.bank.seq_len()
    }

    fn predict(&mut self, inputs: &[f32], n: usize) -> Result<Vec<(u32, u32, u32)>> {
        self.scratch.clear();
        self.bank.infer_raw(inputs, n, &mut self.scratch)?;
        let mode = self.bank.mode;
        Ok(self
            .scratch
            .chunks_exact(HEAD_OUT)
            .take(n)
            .map(|row| decode_row(row, mode))
            .collect())
    }

    fn served(&self) -> u64 {
        self.bank.inferences
    }

    fn context_mode(&self) -> ContextMode {
        if self.bank.model_name().contains("ithemal") {
            ContextMode::Ithemal
        } else {
            ContextMode::SimNet
        }
    }
}

/// Analytical table predictor: derives latencies directly from the encoded
/// features with the same formulas the DES uses for first-order effects
/// (cache level -> latency, mispredict -> bubble). Deterministic, fast,
/// artifact-free. Used by coordinator unit tests and as an ablation
/// baseline; NOT meant to be accurate on contended scenarios.
pub struct TablePredictor {
    seq: usize,
    served: u64,
    /// Latency (cycles) per data access level 1..3.
    pub level_latency: [u32; 3],
    pub mispredict_bubble: u32,
}

impl TablePredictor {
    pub fn new(seq: usize) -> Self {
        TablePredictor {
            seq,
            served: 0,
            level_latency: [5, 34, 174],
            mispredict_bubble: 10,
        }
    }

    fn predict_one(&self, slot0: &[f32]) -> (u32, u32, u32) {
        // Decode the features we planted in features::encode_static.
        let is_load = slot0[features::OP_BASE + 3] > 0.5;
        let is_store = slot0[features::OP_BASE + 4] > 0.5;
        let op_lat = (slot0[features::OP_BASE + 2] * 20.0).round() as u32;
        let mispredict = slot0[features::FETCH_HIST_BASE] > 0.5;
        let fetch_level = (slot0[features::FETCH_HIST_BASE + 1] * 3.0).round() as u32;
        let data_level = (slot0[features::DATA_HIST_BASE] * 3.0).round() as u32;

        let mut f = 0u32;
        if fetch_level > 1 {
            f += self.level_latency[(fetch_level as usize - 1).min(2)];
        }
        if mispredict {
            f += self.mispredict_bubble;
        }
        let mut e = 4 + op_lat; // frontend depth + op latency
        if is_load && data_level >= 1 {
            e += self.level_latency[(data_level as usize - 1).min(2)];
        }
        let s = if is_store {
            e + 2 + self.level_latency[(data_level.max(1) as usize - 1).min(2)]
        } else {
            0
        };
        (f, e, s)
    }
}

impl LatencyPredictor for TablePredictor {
    fn seq_len(&self) -> usize {
        self.seq
    }

    fn predict(&mut self, inputs: &[f32], n: usize) -> Result<Vec<(u32, u32, u32)>> {
        let width = self.seq * NUM_FEATURES;
        self.served += n as u64;
        Ok((0..n).map(|i| self.predict_one(&inputs[i * width..i * width + NUM_FEATURES])).collect())
    }

    fn served(&self) -> u64 {
        self.served
    }

    /// The table is pure math over a few constants, so a fork is just a
    /// fresh table with the same parameters.
    fn fork(&self) -> Option<Box<dyn LatencyPredictor>> {
        Some(Box::new(TablePredictor {
            seq: self.seq,
            served: 0,
            level_latency: self.level_latency,
            mispredict_bubble: self.mispredict_bubble,
        }))
    }

    fn absorb_served(&mut self, n: u64) {
        self.served += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::SimConfig;
    use crate::features::ContextTracker;
    use crate::history::HistoryInfo;
    use crate::isa::{Inst, OpClass};

    #[test]
    fn table_predictor_reflects_levels() {
        let cfg = SimConfig::default_o3();
        let tracker = ContextTracker::new(&cfg);
        let mut p = TablePredictor::new(8);
        let mut buf = vec![0.0f32; 8 * NUM_FEATURES];

        let ld =
            Inst { pc: 0x100, op: OpClass::Load, mem_addr: 0x9000, mem_size: 8, ..Default::default() };
        let h1 = HistoryInfo { fetch_level: 1, data_level: 1, ..Default::default() };
        tracker.encode_input(&ld, &h1, 8, &mut buf);
        let (f1, e1, _) = p.predict(&buf, 1).unwrap()[0];
        let h3 = HistoryInfo { fetch_level: 1, data_level: 3, ..Default::default() };
        tracker.encode_input(&ld, &h3, 8, &mut buf);
        let (_, e3, _) = p.predict(&buf, 1).unwrap()[0];
        assert!(e3 > e1 + 100, "memory-level load must be slower: {e1} vs {e3}");
        assert_eq!(f1, 0, "warm fetch has no stall");
        assert_eq!(p.served(), 2);
    }

    #[test]
    fn table_predictor_mispredict_bubble() {
        let cfg = SimConfig::default_o3();
        let tracker = ContextTracker::new(&cfg);
        let mut p = TablePredictor::new(4);
        let mut buf = vec![0.0f32; 4 * NUM_FEATURES];
        let br = Inst {
            pc: 0x200,
            op: OpClass::CondBranch,
            taken: true,
            target: 0x300,
            ..Default::default()
        };
        let h = HistoryInfo { mispredict: true, fetch_level: 1, ..Default::default() };
        tracker.encode_input(&br, &h, 4, &mut buf);
        let (f, _, _) = p.predict(&buf, 1).unwrap()[0];
        assert!(f >= 10);
    }

    #[test]
    fn table_predictor_batch_matches_single() {
        let cfg = SimConfig::default_o3();
        let tracker = ContextTracker::new(&cfg);
        let mut p = TablePredictor::new(4);
        let mut one = vec![0.0f32; 4 * NUM_FEATURES];
        let mut many = vec![0.0f32; 3 * 4 * NUM_FEATURES];
        let insts: Vec<Inst> = (0..3)
            .map(|k| Inst {
                pc: 0x100 + 4 * k,
                op: if k == 1 { OpClass::Load } else { OpClass::IntAlu },
                mem_addr: 0x8000,
                mem_size: 8,
                ..Default::default()
            })
            .collect();
        let h = HistoryInfo { fetch_level: 1, data_level: 2, ..Default::default() };
        let mut singles = Vec::new();
        for (k, i) in insts.iter().enumerate() {
            tracker.encode_input(i, &h, 4, &mut one);
            many[k * 4 * NUM_FEATURES..(k + 1) * 4 * NUM_FEATURES].copy_from_slice(&one);
            singles.push(p.predict(&one, 1).unwrap()[0]);
        }
        let batch = p.predict(&many, 3).unwrap();
        assert_eq!(batch, singles);
    }
}
