//! Memory-mapped zero-copy `.smt` trace access.
//!
//! The hot read path maps the whole trace read-only with raw `mmap`/`munmap`
//! syscalls (no libc dependency) and decodes records straight out of the
//! mapping: no `BufReader` staging copies, no per-record `read_exact`. On
//! targets without the syscall shim ([`MmapTrace::supported`] is false) the
//! constructor fails with `ErrorKind::Unsupported` and every caller falls
//! back to the buffered [`super::TraceReader`] path, which shares the same
//! header/length validation and error text.

use std::fs::File;
use std::io;
use std::path::Path;

use super::{open_validated, TraceRecord, HEADER_SIZE, RECORD_SIZE};

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    /// Raw syscalls are wired up for this target.
    pub const SUPPORTED: bool = true;

    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;
    const MADV_SEQUENTIAL: usize = 2;

    #[cfg(target_arch = "x86_64")]
    unsafe fn sys_mmap(len: usize, prot: usize, flags: usize, fd: isize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") 9isize => ret, // SYS_mmap
            in("rdi") 0usize,
            in("rsi") len,
            in("rdx") prot,
            in("r10") flags,
            in("r8") fd,
            in("r9") 0usize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ret
    }

    #[cfg(target_arch = "x86_64")]
    unsafe fn sys_madvise(addr: usize, len: usize, advice: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") 28isize => ret, // SYS_madvise
            in("rdi") addr,
            in("rsi") len,
            in("rdx") advice,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ret
    }

    #[cfg(target_arch = "x86_64")]
    unsafe fn sys_munmap(addr: usize, len: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") 11isize => ret, // SYS_munmap
            in("rdi") addr,
            in("rsi") len,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn sys_mmap(len: usize, prot: usize, flags: usize, fd: isize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") 222usize, // SYS_mmap
            inlateout("x0") 0usize => ret,
            in("x1") len,
            in("x2") prot,
            in("x3") flags,
            in("x4") fd,
            in("x5") 0usize,
            options(nostack)
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn sys_madvise(addr: usize, len: usize, advice: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") 233usize, // SYS_madvise
            inlateout("x0") addr as isize => ret,
            in("x1") len,
            in("x2") advice,
            options(nostack)
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn sys_munmap(addr: usize, len: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") 215usize, // SYS_munmap
            inlateout("x0") addr as isize => ret,
            in("x1") len,
            options(nostack)
        );
        ret
    }

    /// A read-only `MAP_PRIVATE` mapping of the first `len` bytes of a file.
    pub struct Map {
        ptr: *const u8,
        len: usize,
    }

    // SAFETY: the mapping is immutable (PROT_READ) and exclusively owned by
    // this handle, so sharing references across threads is sound.
    unsafe impl Send for Map {}
    unsafe impl Sync for Map {}

    impl Map {
        pub fn new(file: &File, len: usize) -> io::Result<Map> {
            // SAFETY: plain mmap of a file descriptor we hold open; the
            // kernel validates every argument and reports errors as
            // negative errno values in [-4095, -1].
            let ret =
                unsafe { sys_mmap(len, PROT_READ, MAP_PRIVATE, file.as_raw_fd() as isize) };
            if (-4095..0).contains(&ret) {
                return Err(io::Error::from_raw_os_error(-ret as i32));
            }
            Ok(Map { ptr: ret as *const u8, len })
        }

        pub fn bytes(&self) -> &[u8] {
            // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
            // bytes, unmapped only in Drop.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }

        /// Best-effort `madvise(MADV_SEQUENTIAL)`: trace reads are
        /// forward scans, so ask the kernel for aggressive readahead
        /// and early reclaim of pages behind the cursors. Advice only —
        /// errors are ignored (the mapping stays fully functional).
        pub fn advise_sequential(&self) {
            // SAFETY: advising the exact live range mmap returned.
            unsafe { sys_madvise(self.ptr as usize, self.len, MADV_SEQUENTIAL) };
        }
    }

    impl Drop for Map {
        fn drop(&mut self) {
            // SAFETY: unmapping the exact range mmap returned.
            unsafe { sys_munmap(self.ptr as usize, self.len) };
        }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    use std::fs::File;
    use std::io;

    /// No syscall shim on this target: callers take the buffered path.
    pub const SUPPORTED: bool = false;

    pub struct Map(());

    impl Map {
        pub fn new(_file: &File, _len: usize) -> io::Result<Map> {
            Err(io::Error::new(io::ErrorKind::Unsupported, "mmap is not wired up on this target"))
        }

        pub fn bytes(&self) -> &[u8] {
            &[]
        }

        pub fn advise_sequential(&self) {}
    }
}

/// A validated, memory-mapped `.smt` trace.
///
/// Records are exposed as bounds-checked views into the mapping and decoded
/// on demand — the file's bytes are never staged through an intermediate
/// read buffer.
pub struct MmapTrace {
    map: sys::Map,
    count: u64,
}

impl MmapTrace {
    /// Whether the zero-copy path exists on this target.
    pub fn supported() -> bool {
        sys::SUPPORTED
    }

    /// Map `path`, validating magic, record count, and file length with the
    /// same checks (and error text) as the buffered [`super::TraceReader`].
    pub fn open(path: &Path) -> io::Result<MmapTrace> {
        let (file, count, len) = open_validated(path)?;
        MmapTrace::from_file(&file, count, len)
    }

    /// Map an already-validated trace file of `file_len` bytes.
    pub(crate) fn from_file(file: &File, count: u64, file_len: u64) -> io::Result<MmapTrace> {
        let map = sys::Map::new(file, file_len as usize)?;
        map.advise_sequential();
        Ok(MmapTrace { map, count })
    }

    /// Records promised by the header.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Total bytes mapped (header + records).
    pub fn mapped_len(&self) -> usize {
        self.map.bytes().len()
    }

    /// Bounds-checked raw view of record `i`.
    pub fn record_bytes(&self, i: u64) -> &[u8; RECORD_SIZE] {
        assert!(i < self.count, "record {i} out of bounds ({} records)", self.count);
        let start = HEADER_SIZE + i as usize * RECORD_SIZE;
        self.map.bytes()[start..start + RECORD_SIZE].try_into().unwrap()
    }

    /// Decode record `i` straight out of the mapping.
    pub fn get(&self, i: u64) -> TraceRecord {
        TraceRecord::decode(self.record_bytes(i))
    }

    /// Stream every record, decoding out of the mapping with no staging.
    pub fn iter(&self) -> impl Iterator<Item = TraceRecord> + '_ {
        (0..self.count).map(|i| self.get(i))
    }

    /// Decode the whole trace in one pass.
    pub fn decode_all(&self) -> Vec<TraceRecord> {
        let mut out = Vec::with_capacity(self.count as usize);
        out.extend(self.iter());
        out
    }
}
