//! Binary interchange formats.
//!
//! * `.smt` — instruction traces: one fixed-size record per retired
//!   instruction (static properties + history-context results + the three
//!   ground-truth latencies). Produced by the DES (`repro gen-trace`),
//!   consumed by the ML simulator and by dataset building. This plays the
//!   role of the paper's modified-gem5 trace dump (§2.4).
//! * `.smd` — ML datasets: flattened (features, labels) sample tensors
//!   ready for training. Produced by `repro build-dataset` using the exact
//!   same [`crate::features::ContextTracker`] the simulator uses online;
//!   consumed by `python/compile/train.py`.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::des::ExecutedInst;
use crate::features::{ContextMode, ContextTracker, NUM_FEATURES};
use crate::history::HistoryInfo;
use crate::isa::{Inst, OpClass, MAX_DST_REGS, MAX_SRC_REGS};

pub mod mmap;
pub mod store;

pub use store::{RecordCursor, RecordStore, RecordsView, ResidentGauge, DEFAULT_STREAM_WINDOW};

/// Size in bytes of one on-disk trace record.
pub const RECORD_SIZE: usize = 64;

/// Size in bytes of the `.smt` header (magic + u64 record count).
pub const HEADER_SIZE: usize = 12;

const SMT_MAGIC: &[u8; 4] = b"SMT1";
const SMD_MAGIC: &[u8; 4] = b"SMD1";

/// One trace record (flattened [`ExecutedInst`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub inst: Inst,
    pub hist: HistoryInfo,
    pub f_lat: u32,
    pub e_lat: u32,
    pub s_lat: u32,
}

impl From<&ExecutedInst> for TraceRecord {
    fn from(e: &ExecutedInst) -> Self {
        TraceRecord { inst: e.inst, hist: e.hist, f_lat: e.f_lat, e_lat: e.e_lat, s_lat: e.s_lat }
    }
}

fn pack_bools(bits: &[bool]) -> u8 {
    bits.iter().enumerate().fold(0u8, |acc, (i, &b)| acc | ((b as u8) << i))
}

fn unpack_bool<const N: usize>(byte: u8) -> [bool; N] {
    let mut out = [false; N];
    for (i, o) in out.iter_mut().enumerate() {
        *o = (byte >> i) & 1 == 1;
    }
    out
}

impl TraceRecord {
    /// Serialize into a fixed [`RECORD_SIZE`]-byte buffer.
    pub fn encode(&self, buf: &mut [u8; RECORD_SIZE]) {
        buf.fill(0);
        buf[0..8].copy_from_slice(&self.inst.pc.to_le_bytes());
        buf[8] = self.inst.op.code();
        for (k, &r) in self.inst.srcs.iter().enumerate() {
            buf[9 + k] = r as u8;
        }
        for (k, &r) in self.inst.dsts.iter().enumerate() {
            buf[17 + k] = r as u8;
        }
        buf[23..31].copy_from_slice(&self.inst.mem_addr.to_le_bytes());
        buf[31] = self.inst.mem_size;
        buf[32..40].copy_from_slice(&self.inst.target.to_le_bytes());
        buf[40] = self.inst.taken as u8;
        buf[41] = self.hist.mispredict as u8;
        buf[42] = self.hist.fetch_level;
        buf[43] = pack_bools(&self.hist.fetch_walk);
        buf[44] = pack_bools(&self.hist.fetch_wb);
        buf[45] = self.hist.data_level;
        buf[46] = pack_bools(&self.hist.data_walk);
        buf[47] = pack_bools(&self.hist.data_wb);
        buf[48..52].copy_from_slice(&self.f_lat.to_le_bytes());
        buf[52..56].copy_from_slice(&self.e_lat.to_le_bytes());
        buf[56..60].copy_from_slice(&self.s_lat.to_le_bytes());
    }

    /// Deserialize from a [`RECORD_SIZE`]-byte buffer.
    pub fn decode(buf: &[u8; RECORD_SIZE]) -> Self {
        let mut inst = Inst {
            pc: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            op: OpClass::from_code(buf[8]),
            mem_addr: u64::from_le_bytes(buf[23..31].try_into().unwrap()),
            mem_size: buf[31],
            target: u64::from_le_bytes(buf[32..40].try_into().unwrap()),
            taken: buf[40] != 0,
            ..Default::default()
        };
        for k in 0..MAX_SRC_REGS {
            inst.srcs[k] = buf[9 + k] as i8;
        }
        for k in 0..MAX_DST_REGS {
            inst.dsts[k] = buf[17 + k] as i8;
        }
        let hist = HistoryInfo {
            mispredict: buf[41] != 0,
            fetch_level: buf[42],
            fetch_walk: unpack_bool::<3>(buf[43]),
            fetch_wb: unpack_bool::<2>(buf[44]),
            data_level: buf[45],
            data_walk: unpack_bool::<3>(buf[46]),
            data_wb: unpack_bool::<3>(buf[47]),
        };
        TraceRecord {
            inst,
            hist,
            f_lat: u32::from_le_bytes(buf[48..52].try_into().unwrap()),
            e_lat: u32::from_le_bytes(buf[52..56].try_into().unwrap()),
            s_lat: u32::from_le_bytes(buf[56..60].try_into().unwrap()),
        }
    }
}

/// Streaming `.smt` writer.
pub struct TraceWriter {
    w: BufWriter<File>,
    count: u64,
}

impl TraceWriter {
    pub fn create(path: &Path) -> io::Result<Self> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(SMT_MAGIC)?;
        w.write_all(&0u64.to_le_bytes())?; // count back-patched on finish
        Ok(TraceWriter { w, count: 0 })
    }

    pub fn write(&mut self, rec: &TraceRecord) -> io::Result<()> {
        let mut buf = [0u8; RECORD_SIZE];
        rec.encode(&mut buf);
        self.w.write_all(&buf)?;
        self.count += 1;
        Ok(())
    }

    /// Flush and back-patch the record count.
    pub fn finish(mut self) -> io::Result<u64> {
        use std::io::Seek;
        self.w.flush()?;
        let mut f = self.w.into_inner()?;
        f.seek(io::SeekFrom::Start(4))?;
        f.write_all(&self.count.to_le_bytes())?;
        Ok(self.count)
    }
}

/// Validate an `.smt` payload length against the header's record count.
///
/// Both the mmap and buffered paths reject mid-record truncation here, with
/// identical error text naming the byte offset of the damage. Extra
/// *complete* records beyond `count` are tolerated (a crashed writer leaves
/// count 0 and the trailing records are simply ignored).
fn check_payload(count: u64, file_len: u64) -> io::Result<()> {
    let payload = file_len - HEADER_SIZE as u64;
    let whole = payload / RECORD_SIZE as u64;
    if payload % RECORD_SIZE as u64 != 0 {
        let off = HEADER_SIZE as u64 + whole * RECORD_SIZE as u64;
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("trace truncated: partial record at byte offset {off} ({file_len}-byte file)"),
        ));
    }
    if whole < count {
        let off = HEADER_SIZE as u64 + whole * RECORD_SIZE as u64;
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "trace truncated: header promises {count} records but the file ends at byte \
                 offset {off} after {whole} complete records"
            ),
        ));
    }
    Ok(())
}

/// Open an `.smt` file and validate magic, header, and payload length.
///
/// Returns the file (positioned just past the header), the record count,
/// and the file's byte length. Every read path — buffered and mmap — goes
/// through here, so truncation errors are identical everywhere.
pub(crate) fn open_validated(path: &Path) -> io::Result<(File, u64, u64)> {
    let mut f = File::open(path)?;
    let mut header = [0u8; HEADER_SIZE];
    f.read_exact(&mut header)?;
    if header[0..4] != SMT_MAGIC[..] {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "not an .smt trace"));
    }
    let count = u64::from_le_bytes(header[4..12].try_into().unwrap());
    let len = f.metadata()?.len();
    check_payload(count, len)?;
    Ok((f, count, len))
}

/// Streaming `.smt` reader (buffered fallback path).
pub struct TraceReader {
    r: BufReader<File>,
    remaining: u64,
    /// Total records in the file.
    pub count: u64,
}

impl TraceReader {
    /// Open and validate. Rejects bad magic, a short header, and any
    /// mid-record truncation (naming the byte offset) before the first read.
    pub fn open(path: &Path) -> io::Result<Self> {
        let (f, count, _len) = open_validated(path)?;
        Ok(TraceReader { r: BufReader::new(f), remaining: count, count })
    }
}

impl Iterator for TraceReader {
    type Item = io::Result<TraceRecord>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let mut buf = [0u8; RECORD_SIZE];
        match self.r.read_exact(&mut buf) {
            Ok(()) => Some(Ok(TraceRecord::decode(&buf))),
            Err(e) => {
                self.remaining = 0;
                Some(Err(e))
            }
        }
    }
}

/// How a simulation's input bytes reached memory.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InputStats {
    /// Bytes served through the zero-copy mmap path.
    pub bytes_mapped: u64,
    /// Bytes staged through buffered `read` copies.
    pub bytes_copied: u64,
    /// Peak decoded records resident at once while reading a trace
    /// file: the full record count on the full-decode path, the sum of
    /// per-cursor window maxima on the streaming path (at most
    /// subtraces × `window_records`). Zero for in-memory and bench
    /// sources, whose records the caller already holds.
    pub peak_resident_records: u64,
    /// Configured streaming decode window in records (0 = the run was
    /// not streamed: full decode or an in-memory source).
    pub window_records: u64,
}

/// Open an `.smt` trace as a [`RecordStore`] — THE single validated
/// open path every consumer (full decode, streaming, buffered) shares.
///
/// `use_mmap: false` — or a target without the syscall shim — takes the
/// buffered [`TraceReader`]-style path. With `streaming: true` a
/// successful mapping is returned as a windowed [`RecordStore::Mapped`]
/// (`window == 0` picks [`DEFAULT_STREAM_WINDOW`]) whose cursors decode
/// records on demand; every other combination decodes the whole trace
/// up front. All paths share `open_validated`'s checks (magic, header,
/// mid-record truncation with byte offsets) and produce bit-identical
/// records; the returned [`InputStats`] says which path served the
/// bytes and what the residency bound is. A streaming store's
/// `peak_resident_records` starts at zero and is read off the store's
/// gauge after the run that consumed its cursors.
pub fn open_store(
    path: &Path,
    use_mmap: bool,
    streaming: bool,
    window: usize,
) -> io::Result<(RecordStore<'static>, InputStats)> {
    let (file, count, len) = open_validated(path)?;
    if use_mmap {
        // Map failures (unsupported target, exotic filesystem) fall back to
        // the buffered path below; validation already happened above.
        if let Ok(m) = mmap::MmapTrace::from_file(&file, count, len) {
            let mapped = m.mapped_len() as u64;
            if streaming {
                let store = RecordStore::mapped(m, window);
                let stats = InputStats {
                    bytes_mapped: mapped,
                    bytes_copied: 0,
                    peak_resident_records: 0, // read off the gauge post-run
                    window_records: store.window_records(),
                };
                return Ok((store, stats));
            }
            let stats = InputStats {
                bytes_mapped: mapped,
                bytes_copied: 0,
                peak_resident_records: count,
                window_records: 0,
            };
            return Ok((RecordStore::from_vec(m.decode_all()), stats));
        }
    }
    let mut r = BufReader::new(file);
    let mut recs = Vec::with_capacity(count as usize);
    let mut buf = [0u8; RECORD_SIZE];
    for _ in 0..count {
        r.read_exact(&mut buf)?;
        recs.push(TraceRecord::decode(&buf));
    }
    let copied = HEADER_SIZE as u64 + count * RECORD_SIZE as u64;
    let stats = InputStats {
        bytes_mapped: 0,
        bytes_copied: copied,
        peak_resident_records: count,
        window_records: 0,
    };
    Ok((RecordStore::from_vec(recs), stats))
}

/// Read a whole trace into memory (full decode), preferring the
/// zero-copy mmap path. A thin wrapper over [`open_store`] with
/// streaming off; see there for the validation and fallback rules.
pub fn load_trace(path: &Path, use_mmap: bool) -> io::Result<(Vec<TraceRecord>, InputStats)> {
    let (store, stats) = open_store(path, use_mmap, false, 0)?;
    Ok((store.into_records(), stats))
}

/// Read a whole trace into memory — the **full decode** convenience
/// wrapper over [`open_store`]. Every record is materialized up front;
/// for bounded-memory access open a store and stream through its
/// cursors instead.
pub fn read_trace(path: &Path) -> io::Result<Vec<TraceRecord>> {
    Ok(load_trace(path, true)?.0)
}

/// A simulation input: in-memory records, a synthetic benchmark, or an
/// on-disk `.smt` trace file.
///
/// This is the one input shape every front end — the [`crate::api::Simulation`]
/// builder, the CLI, and the job server — resolves through a single code
/// path (and a single set of error messages). `Bench` names are looked up
/// and generated by the API layer; `File` sources are read via
/// [`load_trace`], so the mmap/buffered choice and the truncation checks
/// are identical everywhere.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceSource<'a> {
    /// Borrowed, already-decoded records.
    Records(&'a [TraceRecord]),
    /// A named synthetic benchmark run for `n` instructions.
    Bench {
        /// Benchmark name (see `workload::find`).
        name: String,
        /// Instructions to generate.
        n: u64,
    },
    /// An on-disk `.smt` trace.
    File {
        /// Path to the trace file.
        path: PathBuf,
        /// Prefer the zero-copy mmap path (silently falls back to buffered
        /// reads on targets without mmap).
        mmap: bool,
    },
}

impl<'a> TraceSource<'a> {
    /// Borrow already-decoded records.
    pub fn records(records: &'a [TraceRecord]) -> TraceSource<'a> {
        TraceSource::Records(records)
    }
}

impl TraceSource<'static> {
    /// A named synthetic benchmark run for `n` instructions.
    pub fn bench(name: impl Into<String>, n: u64) -> TraceSource<'static> {
        TraceSource::Bench { name: name.into(), n }
    }

    /// An on-disk `.smt` trace, read via mmap where available.
    pub fn file(path: impl Into<PathBuf>) -> TraceSource<'static> {
        TraceSource::File { path: path.into(), mmap: true }
    }

    /// An on-disk `.smt` trace, forced onto the buffered read path.
    pub fn file_buffered(path: impl Into<PathBuf>) -> TraceSource<'static> {
        TraceSource::File { path: path.into(), mmap: false }
    }
}

// ---------------------------------------------------------------------
// Dataset building (.smd)
// ---------------------------------------------------------------------

/// Options for converting a trace into an ML dataset.
pub struct DatasetOptions {
    /// Instruction slots per sample (1 current + context; power of two).
    pub seq_len: usize,
    /// Drop duplicate samples (paper §2.4 "we eliminate such duplication").
    pub dedup: bool,
    /// Keep at most this many samples (0 = unlimited).
    pub limit: u64,
    /// Context-selection mode (SimNet vs Ithemal baseline).
    pub mode: ContextMode,
    /// Configuration feature broadcast into every slot (ROB study; 0 off).
    pub cfg_feature: f32,
}

impl Default for DatasetOptions {
    fn default() -> Self {
        DatasetOptions {
            seq_len: 64,
            dedup: true,
            limit: 0,
            mode: ContextMode::SimNet,
            cfg_feature: 0.0,
        }
    }
}

/// Streaming `.smd` writer (header + raw little-endian f32 samples).
pub struct DatasetWriter {
    w: BufWriter<File>,
    seq_len: u32,
    nfeat: u32,
    count: u64,
}

impl DatasetWriter {
    pub fn create(path: &Path, seq_len: usize) -> io::Result<Self> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(SMD_MAGIC)?;
        w.write_all(&(seq_len as u32).to_le_bytes())?;
        w.write_all(&(NUM_FEATURES as u32).to_le_bytes())?;
        w.write_all(&0u64.to_le_bytes())?;
        Ok(DatasetWriter { w, seq_len: seq_len as u32, nfeat: NUM_FEATURES as u32, count: 0 })
    }

    /// Write one sample: `features` of length `seq_len * NUM_FEATURES` and
    /// the three raw-cycle labels (F, E, S).
    pub fn write(&mut self, features: &[f32], labels: [f32; 3]) -> io::Result<()> {
        debug_assert_eq!(features.len(), (self.seq_len * self.nfeat) as usize);
        // Safety-free raw serialization: f32 -> LE bytes.
        let mut bytes = Vec::with_capacity(features.len() * 4 + 12);
        for &v in features {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for &l in &labels {
            bytes.extend_from_slice(&l.to_le_bytes());
        }
        self.w.write_all(&bytes)?;
        self.count += 1;
        Ok(())
    }

    pub fn finish(mut self) -> io::Result<u64> {
        use std::io::Seek;
        self.w.flush()?;
        let mut f = self.w.into_inner()?;
        f.seek(io::SeekFrom::Start(12))?;
        f.write_all(&self.count.to_le_bytes())?;
        Ok(self.count)
    }

    /// Samples written so far.
    pub fn count(&self) -> u64 {
        self.count
    }
}

/// FNV-1a over the raw bytes of a sample, for dedup.
fn sample_hash(features: &[f32], labels: &[f32; 3]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |v: f32| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    features.iter().for_each(|&v| eat(v));
    labels.iter().for_each(|&v| eat(v));
    h
}

/// Append samples from `records` into an open writer (shared dedup set).
/// Used directly by mixed-configuration dataset builds (ROB study).
pub fn append_dataset<'a, I>(
    records: I,
    cfg: &crate::des::SimConfig,
    opts: &DatasetOptions,
    writer: &mut DatasetWriter,
    seen: &mut std::collections::HashSet<u64>,
) -> io::Result<u64>
where
    I: Iterator<Item = &'a TraceRecord>,
{
    let mut tracker = ContextTracker::with_mode(cfg, opts.mode);
    tracker.cfg_feature = opts.cfg_feature;
    let mut buf = vec![0.0f32; opts.seq_len * NUM_FEATURES];
    let mut dups = 0u64;
    for rec in records {
        if opts.limit > 0 && writer.count >= opts.limit {
            break;
        }
        tracker.encode_input(&rec.inst, &rec.hist, opts.seq_len, &mut buf);
        let labels = [rec.f_lat as f32, rec.e_lat as f32, rec.s_lat as f32];
        if !opts.dedup || seen.insert(sample_hash(&buf, &labels)) {
            writer.write(&buf, labels)?;
        } else {
            dups += 1;
        }
        tracker.push(&rec.inst, &rec.hist, rec.f_lat, rec.e_lat, rec.s_lat);
    }
    Ok(dups)
}

/// Build an `.smd` dataset from trace records: replays the context tracker
/// with ground-truth latencies and emits one sample per instruction.
/// Returns (written, deduplicated-away).
pub fn build_dataset<'a, I>(
    records: I,
    cfg: &crate::des::SimConfig,
    opts: &DatasetOptions,
    out: &Path,
) -> io::Result<(u64, u64)>
where
    I: Iterator<Item = &'a TraceRecord>,
{
    let mut writer = DatasetWriter::create(out, opts.seq_len)?;
    let mut seen = std::collections::HashSet::new();
    let dups = append_dataset(records, cfg, opts, &mut writer, &mut seen)?;
    let written = writer.finish()?;
    Ok((written, dups))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{simulate, SimConfig};
    use crate::workload::find;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("simnet_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn record_roundtrip() {
        let mut rec = TraceRecord {
            inst: Inst {
                pc: 0xDEAD_BEE0,
                op: OpClass::Store,
                mem_addr: 0x1234_5678,
                mem_size: 8,
                target: 0,
                taken: false,
                ..Default::default()
            },
            hist: HistoryInfo {
                mispredict: true,
                fetch_level: 2,
                fetch_walk: [true, false, true],
                fetch_wb: [false, true],
                data_level: 3,
                data_walk: [false, false, true],
                data_wb: [true, false, false],
            },
            f_lat: 7,
            e_lat: 312,
            s_lat: 901,
        };
        rec.inst.srcs[0] = 5;
        rec.inst.srcs[1] = -1;
        rec.inst.dsts[0] = 63;
        let mut buf = [0u8; RECORD_SIZE];
        rec.encode(&mut buf);
        let back = TraceRecord::decode(&buf);
        assert_eq!(rec, back);
    }

    #[test]
    fn trace_file_roundtrip() {
        let path = tmp("roundtrip.smt");
        let cfg = SimConfig::default_o3();
        let b = find("namd").unwrap();
        let mut written = Vec::new();
        let mut w = TraceWriter::create(&path).unwrap();
        simulate(&cfg, b.workload(0).stream(), 2000, |e| {
            let rec = TraceRecord::from(e);
            w.write(&rec).unwrap();
            written.push(rec);
        });
        let n = w.finish().unwrap();
        assert_eq!(n, 2000);
        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), 2000);
        assert_eq!(&back[..], &written[..]);
    }

    #[test]
    fn reader_rejects_bad_magic() {
        let p = tmp("bad_magic.smt");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let err = TraceReader::open(&p).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn reader_rejects_truncated_header() {
        // Valid magic but the 8-byte record count is cut short.
        let p = tmp("short_header.smt");
        std::fs::write(&p, b"SMT1\x02\x00").unwrap();
        let err = TraceReader::open(&p).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn truncated_final_record_is_rejected_at_open() {
        // Header promises 2 records but the last one is cut short: every
        // open path (buffered reader, mmap, load_trace) must refuse up
        // front, naming the byte offset where the partial record starts
        // (header 12 + one intact record 64 = 76).
        let p = tmp("short_tail.smt");
        let cfg = SimConfig::default_o3();
        let b = find("xz").unwrap();
        let mut w = TraceWriter::create(&p).unwrap();
        simulate(&cfg, b.workload(0).stream(), 2, |e| {
            w.write(&TraceRecord::from(e)).unwrap();
        });
        assert_eq!(w.finish().unwrap(), 2);
        let full = std::fs::metadata(&p).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(full - 10).unwrap();
        drop(f);
        let err = TraceReader::open(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("byte offset 76"), "{err}");
        let merr = mmap::MmapTrace::open(&p).unwrap_err();
        assert_eq!(merr.to_string(), err.to_string());
        for use_mmap in [true, false] {
            let lerr = load_trace(&p, use_mmap).unwrap_err();
            assert_eq!(lerr.to_string(), err.to_string());
        }
    }

    #[test]
    fn header_count_beyond_file_is_rejected_at_open() {
        // One complete record on disk but a header promising three: the
        // error names the promised count and where the file actually ends.
        let p = tmp("overcount.smt");
        let cfg = SimConfig::default_o3();
        let b = find("xz").unwrap();
        let mut w = TraceWriter::create(&p).unwrap();
        simulate(&cfg, b.workload(0).stream(), 1, |e| {
            w.write(&TraceRecord::from(e)).unwrap();
        });
        assert_eq!(w.finish().unwrap(), 1);
        {
            use std::io::Seek;
            let mut f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
            f.seek(io::SeekFrom::Start(4)).unwrap();
            f.write_all(&3u64.to_le_bytes()).unwrap();
        }
        let err = TraceReader::open(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        let msg = err.to_string();
        assert!(msg.contains("promises 3 records"), "{msg}");
        assert!(msg.contains("byte offset 76"), "{msg}");
        assert_eq!(load_trace(&p, true).unwrap_err().to_string(), msg);
    }

    #[test]
    fn mmap_and_buffered_reads_are_identical() {
        let p = tmp("mmap_eq.smt");
        let cfg = SimConfig::default_o3();
        let b = find("namd").unwrap();
        let mut w = TraceWriter::create(&p).unwrap();
        simulate(&cfg, b.workload(0).stream(), 500, |e| {
            w.write(&TraceRecord::from(e)).unwrap();
        });
        assert_eq!(w.finish().unwrap(), 500);
        let (mapped, mstats) = load_trace(&p, true).unwrap();
        let (buffered, bstats) = load_trace(&p, false).unwrap();
        assert_eq!(mapped, buffered);
        assert_eq!(
            bstats,
            InputStats {
                bytes_mapped: 0,
                bytes_copied: 12 + 500 * 64,
                peak_resident_records: 500,
                window_records: 0,
            }
        );
        if mmap::MmapTrace::supported() {
            assert_eq!(
                mstats,
                InputStats {
                    bytes_mapped: 12 + 500 * 64,
                    bytes_copied: 0,
                    peak_resident_records: 500,
                    window_records: 0,
                }
            );
            let m = mmap::MmapTrace::open(&p).unwrap();
            assert_eq!(m.count(), 500);
            assert_eq!(m.get(499), buffered[499]);
            assert_eq!(m.iter().count(), 500);
        } else {
            assert_eq!(mstats, bstats);
        }
    }

    #[test]
    fn dataset_builds_and_dedups() {
        let trace_path = tmp("ds.smt");
        let ds_path = tmp("ds.smd");
        let cfg = SimConfig::default_o3();
        let b = find("exchange2").unwrap();
        let mut w = TraceWriter::create(&trace_path).unwrap();
        simulate(&cfg, b.workload(0).stream(), 5000, |e| {
            w.write(&TraceRecord::from(e)).unwrap();
        });
        w.finish().unwrap();
        let recs = read_trace(&trace_path).unwrap();
        let (written, dups) = build_dataset(
            recs.iter(),
            &cfg,
            &DatasetOptions {
                seq_len: 16,
                dedup: true,
                limit: 0,
                mode: ContextMode::SimNet,
                cfg_feature: 0.0,
            },
            &ds_path,
        )
        .unwrap();
        assert_eq!(written + dups, 5000);
        assert!(dups > 0, "a loopy benchmark must produce duplicate samples");
        // Check the .smd header.
        let bytes = std::fs::read(&ds_path).unwrap();
        assert_eq!(&bytes[0..4], SMD_MAGIC);
        let seq = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        let nf = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        let n = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        assert_eq!(seq, 16);
        assert_eq!(nf, NUM_FEATURES as u32);
        assert_eq!(n, written);
        let expect = 20 + n as usize * (16 * NUM_FEATURES + 3) * 4;
        assert_eq!(bytes.len(), expect);
    }

    #[test]
    fn dataset_limit_respected() {
        let trace_path = tmp("lim.smt");
        let ds_path = tmp("lim.smd");
        let cfg = SimConfig::default_o3();
        let b = find("leela").unwrap();
        let mut w = TraceWriter::create(&trace_path).unwrap();
        simulate(&cfg, b.workload(0).stream(), 3000, |e| {
            w.write(&TraceRecord::from(e)).unwrap();
        });
        w.finish().unwrap();
        let recs = read_trace(&trace_path).unwrap();
        let (written, _) = build_dataset(
            recs.iter(),
            &cfg,
            &DatasetOptions {
                seq_len: 8,
                dedup: false,
                limit: 100,
                mode: ContextMode::SimNet,
                cfg_feature: 0.0,
            },
            &ds_path,
        )
        .unwrap();
        assert_eq!(written, 100);
    }
}
