//! Bounded-memory record access: [`RecordStore`], [`RecordsView`], and
//! [`RecordCursor`].
//!
//! PR 7's mmap path still called [`super::mmap::MmapTrace::decode_all`]
//! and materialized every record before the first batch was encoded, so
//! a multi-gigabyte `.smt` file cost a multi-gigabyte resident set. The
//! engine, however, only ever reads each sub-trace *sequentially*: the
//! record at the read position is encoded, scattered, and never touched
//! again (context/history features live in
//! [`crate::features::ContextTracker`], not in past records). A store
//! can therefore hand each sub-trace a cursor that decodes a small
//! window of records on demand and drops it when the cursor moves on —
//! resident memory becomes O(subtraces × window × 64 B) regardless of
//! trace size, and the decoded values are bit-identical to a full
//! decode because [`super::TraceRecord::decode`] runs on the same
//! mapped bytes either way.
//!
//! Three layers:
//!
//! * [`RecordStore`] — owns the input: a decoded in-memory slice/vec,
//!   or an [`super::mmap::MmapTrace`] plus the configured window.
//! * [`RecordsView`] — a cheap, cloneable range of a store. Sub-trace
//!   splitting (`BatchEngine::submit`, the pool's shards) slices views
//!   instead of `&[TraceRecord]` slices.
//! * [`RecordCursor`] — the per-sub-trace reader: zero-cost over
//!   slices, a windowed decode buffer over mappings.
//!
//! Peak-residency accounting is deterministic by construction: each
//! cursor tracks the largest buffer it ever held and adds *deltas* to a
//! shared [`ResidentGauge`], so the gauge's total is the sum of
//! per-cursor maxima — an order-independent quantity no thread
//! interleaving can change, and an upper bound on true simultaneous
//! residency (at most subtraces × window).

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::mmap::MmapTrace;
use super::TraceRecord;

/// Default streaming window in records (64 KiB of decoded trace per
/// sub-trace cursor) when the caller does not configure one.
pub const DEFAULT_STREAM_WINDOW: usize = 1024;

/// Shared peak-residency counter for every cursor of one store.
///
/// Cursors add the *increase* of their own maximum buffer length, so
/// the total is Σ per-cursor maxima: deterministic under any thread
/// schedule, and exactly what `peak_resident_records` reports.
#[derive(Debug, Default)]
pub struct ResidentGauge {
    peak_sum: AtomicU64,
}

impl ResidentGauge {
    fn add(&self, records: u64) {
        // Relaxed is enough: the sum is read only after every cursor
        // has been dropped/joined, and addition is order-independent.
        self.peak_sum.fetch_add(records, Ordering::Relaxed);
    }

    /// Sum of per-cursor maximum buffered record counts so far.
    pub fn peak_sum(&self) -> u64 {
        self.peak_sum.load(Ordering::Relaxed)
    }
}

/// Where a simulation's records live: fully decoded in memory, or
/// mapped on disk and decoded through bounded windows on demand.
pub enum RecordStore<'a> {
    /// Fully decoded records (in-memory sources, bench traces, and the
    /// full-decode file path).
    Memory(Cow<'a, [TraceRecord]>),
    /// A mapped `.smt` trace streamed through per-cursor windows of
    /// `window` records.
    Mapped {
        /// The validated mapping (shared by every view and cursor).
        map: Arc<MmapTrace>,
        /// Decode-window size in records for each cursor.
        window: usize,
        /// Shared peak-residency accounting across all cursors.
        gauge: Arc<ResidentGauge>,
    },
}

impl<'a> RecordStore<'a> {
    /// A store over borrowed, already-decoded records.
    pub fn from_records(records: &'a [TraceRecord]) -> RecordStore<'a> {
        RecordStore::Memory(Cow::Borrowed(records))
    }

    /// Records in the store.
    pub fn len(&self) -> usize {
        match self {
            RecordStore::Memory(r) => r.len(),
            RecordStore::Mapped { map, .. } => map.count() as usize,
        }
    }

    /// Whether the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The configured streaming window in records (0 when the store is
    /// fully decoded — there is no window).
    pub fn window_records(&self) -> u64 {
        match self {
            RecordStore::Memory(_) => 0,
            RecordStore::Mapped { window, .. } => *window as u64,
        }
    }

    /// Peak resident decoded records: the full length for in-memory
    /// stores, the gauge's sum of per-cursor maxima for mapped ones
    /// (meaningful once the run that consumed the cursors finished).
    pub fn peak_resident_records(&self) -> u64 {
        match self {
            RecordStore::Memory(r) => r.len() as u64,
            RecordStore::Mapped { gauge, .. } => gauge.peak_sum(),
        }
    }

    /// A view of the whole store.
    pub fn view(&self) -> RecordsView<'_> {
        match self {
            RecordStore::Memory(r) => RecordsView::Slice(r),
            RecordStore::Mapped { map, window, gauge } => RecordsView::Mapped {
                map: map.clone(),
                start: 0,
                len: map.count() as usize,
                window: *window,
                gauge: gauge.clone(),
            },
        }
    }

    /// Decode the whole store into an owned `Vec` (the "full decode"
    /// escape hatch — [`super::read_trace`] and dataset building).
    pub fn into_records(self) -> Vec<TraceRecord> {
        match self {
            RecordStore::Memory(r) => r.into_owned(),
            RecordStore::Mapped { map, .. } => map.decode_all(),
        }
    }

    /// Re-own any borrowed records so the store can outlive its source
    /// (the job server holds stores across scheduler turns).
    pub fn into_static(self) -> RecordStore<'static> {
        match self {
            RecordStore::Memory(r) => RecordStore::Memory(Cow::Owned(r.into_owned())),
            RecordStore::Mapped { map, window, gauge } => {
                RecordStore::Mapped { map, window, gauge }
            }
        }
    }
}

impl RecordStore<'static> {
    /// A store over owned, already-decoded records.
    pub fn from_vec(records: Vec<TraceRecord>) -> RecordStore<'static> {
        RecordStore::Memory(Cow::Owned(records))
    }

    /// A streaming store over a validated mapping. `window == 0` picks
    /// [`DEFAULT_STREAM_WINDOW`].
    pub fn mapped(map: MmapTrace, window: usize) -> RecordStore<'static> {
        let window = if window == 0 { DEFAULT_STREAM_WINDOW } else { window };
        RecordStore::Mapped {
            map: Arc::new(map),
            window,
            gauge: Arc::new(ResidentGauge::default()),
        }
    }
}

impl<'a> From<&'a [TraceRecord]> for RecordStore<'a> {
    fn from(records: &'a [TraceRecord]) -> RecordStore<'a> {
        RecordStore::from_records(records)
    }
}

/// A contiguous range of a [`RecordStore`]: what the engine's job
/// specs, the pool's shards, and the sequential loop consume instead of
/// `&[TraceRecord]`. Cloning and slicing are cheap (Arc bumps); actual
/// decoding happens in the [`RecordCursor`] each sub-trace opens.
#[derive(Clone)]
pub enum RecordsView<'a> {
    /// A plain slice of decoded records.
    Slice(&'a [TraceRecord]),
    /// A range of a mapped trace, decoded through a windowed cursor.
    Mapped {
        /// The shared mapping.
        map: Arc<MmapTrace>,
        /// First record of this view within the mapping.
        start: u64,
        /// Records in this view.
        len: usize,
        /// Decode-window size in records.
        window: usize,
        /// Shared peak-residency accounting.
        gauge: Arc<ResidentGauge>,
    },
}

impl<'a> RecordsView<'a> {
    /// Records in the view.
    pub fn len(&self) -> usize {
        match self {
            RecordsView::Slice(s) => s.len(),
            RecordsView::Mapped { len, .. } => *len,
        }
    }

    /// Whether the view is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The sub-view covering records `lo..hi` of this view.
    pub fn slice(&self, lo: usize, hi: usize) -> RecordsView<'a> {
        match self {
            RecordsView::Slice(s) => RecordsView::Slice(&s[lo..hi]),
            RecordsView::Mapped { map, start, len, window, gauge } => {
                assert!(lo <= hi && hi <= *len, "view slice {lo}..{hi} out of 0..{len}");
                RecordsView::Mapped {
                    map: map.clone(),
                    start: start + lo as u64,
                    len: hi - lo,
                    window: *window,
                    gauge: gauge.clone(),
                }
            }
        }
    }

    /// Open a sequential reader over the view.
    pub fn cursor(&self) -> RecordCursor<'a> {
        match self {
            RecordsView::Slice(s) => RecordCursor::Slice(s),
            RecordsView::Mapped { map, start, len, window, gauge } => {
                RecordCursor::Mapped(MappedCursor {
                    map: map.clone(),
                    start: *start,
                    len: *len,
                    window: (*window).max(1),
                    buf: Vec::new(),
                    base: 0,
                    max_resident: 0,
                    gauge: gauge.clone(),
                })
            }
        }
    }

    /// Decode the whole view into an owned `Vec`.
    pub fn to_vec(&self) -> Vec<TraceRecord> {
        match self {
            RecordsView::Slice(s) => s.to_vec(),
            RecordsView::Mapped { map, start, len, .. } => {
                (0..*len).map(|i| map.get(start + i as u64)).collect()
            }
        }
    }
}

impl<'a> From<&'a [TraceRecord]> for RecordsView<'a> {
    fn from(records: &'a [TraceRecord]) -> RecordsView<'a> {
        RecordsView::Slice(records)
    }
}

/// Per-sub-trace record reader. Over a slice it is a zero-cost
/// passthrough; over a mapping it keeps a decode buffer of at most
/// `window` records, refilled forward from the requested index. Access
/// within the engine is monotonically non-decreasing (each position is
/// read at encode time and again at scatter time, then advanced), so
/// each record's bytes are decoded exactly once per cursor.
pub enum RecordCursor<'a> {
    /// Zero-cost reads from a decoded slice.
    Slice(&'a [TraceRecord]),
    /// Windowed on-demand decoding from a mapping.
    Mapped(MappedCursor),
}

/// The mapped variant of [`RecordCursor`]: a bounded decode buffer
/// covering records `base..base + buf.len()` of the view.
pub struct MappedCursor {
    map: Arc<MmapTrace>,
    start: u64,
    len: usize,
    window: usize,
    buf: Vec<TraceRecord>,
    base: usize,
    max_resident: usize,
    gauge: Arc<ResidentGauge>,
}

impl RecordCursor<'_> {
    /// Records reachable through the cursor.
    pub fn len(&self) -> usize {
        match self {
            RecordCursor::Slice(s) => s.len(),
            RecordCursor::Mapped(c) => c.len,
        }
    }

    /// Whether the cursor covers no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record `i` of the view (decoding a fresh window on a miss).
    pub fn get(&mut self, i: usize) -> TraceRecord {
        match self {
            RecordCursor::Slice(s) => s[i],
            RecordCursor::Mapped(c) => c.get(i),
        }
    }
}

impl MappedCursor {
    fn get(&mut self, i: usize) -> TraceRecord {
        assert!(i < self.len, "record {i} out of bounds ({} records)", self.len);
        if i < self.base || i >= self.base + self.buf.len() {
            self.refill(i);
        }
        self.buf[i - self.base]
    }

    /// Decode `window` records starting at `i` (clamped to the view's
    /// end), replacing the buffer, and account any new residency peak.
    #[cold]
    fn refill(&mut self, i: usize) {
        let end = (i + self.window).min(self.len);
        let map = &self.map;
        let start = self.start;
        self.buf.clear();
        self.buf.extend((i..end).map(|j| map.get(start + j as u64)));
        self.base = i;
        if self.buf.len() > self.max_resident {
            self.gauge.add((self.buf.len() - self.max_resident) as u64);
            self.max_resident = self.buf.len();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{TraceWriter, HEADER_SIZE, RECORD_SIZE};
    use super::*;
    use crate::des::{simulate, SimConfig};
    use crate::workload::find;
    use std::path::PathBuf;

    fn write_trace(name: &str, n: u64) -> PathBuf {
        let dir = std::env::temp_dir().join("simnet_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let cfg = SimConfig::default_o3();
        let b = find("namd").unwrap();
        let mut w = TraceWriter::create(&path).unwrap();
        simulate(&cfg, b.workload(0).stream(), n, |e| {
            w.write(&TraceRecord::from(e)).unwrap();
        });
        assert_eq!(w.finish().unwrap(), n);
        path
    }

    #[test]
    fn slice_store_is_zero_cost_passthrough() {
        let path = write_trace("slice.smt", 100);
        let recs = super::super::read_trace(&path).unwrap();
        let store = RecordStore::from_records(&recs);
        assert_eq!(store.len(), 100);
        assert_eq!(store.window_records(), 0);
        let view = store.view();
        let mut cur = view.slice(10, 60).cursor();
        assert_eq!(cur.len(), 50);
        for i in 0..50 {
            assert_eq!(cur.get(i), recs[10 + i]);
        }
        assert_eq!(view.to_vec(), recs);
    }

    #[test]
    fn mapped_cursor_matches_full_decode_and_bounds_residency() {
        let path = write_trace("mapped.smt", 233);
        if !MmapTrace::supported() {
            return;
        }
        let full = super::super::read_trace(&path).unwrap();
        let map = MmapTrace::open(&path).unwrap();
        let store = RecordStore::mapped(map, 16);
        assert_eq!(store.len(), 233);
        assert_eq!(store.window_records(), 16);
        let view = store.view();
        // Split into uneven sub-views straddling window boundaries.
        let bounds = [(0usize, 7usize), (7, 100), (100, 233)];
        for &(lo, hi) in &bounds {
            let mut cur = view.slice(lo, hi).cursor();
            for i in 0..hi - lo {
                // Each position is read twice (encode + scatter order).
                assert_eq!(cur.get(i), full[lo + i]);
                assert_eq!(cur.get(i), full[lo + i]);
            }
        }
        // Gauge holds Σ per-cursor maxima: min(window, sub-view len).
        let expect: u64 = bounds.iter().map(|&(lo, hi)| (hi - lo).min(16) as u64).sum();
        assert_eq!(store.peak_resident_records(), expect);
        assert_eq!(view.to_vec(), full);
    }

    #[test]
    fn zero_window_uses_the_default() {
        let path = write_trace("defwin.smt", 10);
        if !MmapTrace::supported() {
            return;
        }
        let store = RecordStore::mapped(MmapTrace::open(&path).unwrap(), 0);
        assert_eq!(store.window_records(), DEFAULT_STREAM_WINDOW as u64);
        // Window larger than the trace: one refill buffers everything.
        let mut cur = store.view().cursor();
        let full = store.view().to_vec();
        for (i, want) in full.iter().enumerate() {
            assert_eq!(cur.get(i), *want);
        }
        drop(cur);
        assert_eq!(store.peak_resident_records(), 10);
    }

    #[test]
    fn into_records_decodes_mapped_stores() {
        let n = 37u64;
        let path = write_trace("intorec.smt", n);
        let full = super::super::read_trace(&path).unwrap();
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            (HEADER_SIZE + n as usize * RECORD_SIZE) as u64
        );
        if MmapTrace::supported() {
            let store = RecordStore::mapped(MmapTrace::open(&path).unwrap(), 8);
            assert_eq!(store.into_records(), full);
        }
        let store = RecordStore::from_vec(full.clone());
        assert_eq!(store.into_static().into_records(), full);
    }
}
