//! The SimNet simulator proper (paper §3): instruction-centric simulation
//! driven by the ML latency predictor.
//!
//! * [`sequential`] — the reference single-stream simulator (§3.2):
//!   predict → push into context queues → advance `curTick` by F.
//! * [`parallel`] — the sub-trace parallel simulator (§3.3): the trace is
//!   split into equally sized contiguous sub-traces, each simulated
//!   sequentially with its own context/clock, with the per-step
//!   predictions of all sub-traces batched into single accelerator calls.
//! * [`engine`] — the shared dynamic-batching engine: many concurrent
//!   jobs, all of whose sub-traces are multiplexed into common predictor
//!   batches with a configurable target batch size (paper §3.3/Figure 9),
//!   optionally pipelined across a pool of encode workers that overlap
//!   feature encoding with prediction ([`EngineOptions`]).
//! * [`pool`] — multi-job pooling over the engine, standing in for the
//!   paper's multi-GPU scaling: shards share one predictor and one batch
//!   stream instead of loading a private executable per thread.

pub mod engine;
pub mod parallel;
pub mod pool;
pub mod sequential;

pub use engine::{BatchEngine, EngineOptions, EngineReport, EngineStats, JobSpec};
#[allow(deprecated)]
pub use parallel::{simulate_parallel, simulate_parallel_cfg};
pub use parallel::{simulate_parallel_with, ParallelOptions};
pub use pool::{simulate_pool, simulate_pool_report, simulate_pool_view, PoolOptions};
pub use sequential::{simulate_sequential, simulate_sequential_progress, simulate_sequential_view};

/// Result of an ML-simulated run.
#[derive(Debug, Clone, Default)]
pub struct SimOutcome {
    pub instructions: u64,
    /// Predicted program cycles (Eq. 1: sum of F plus drain).
    pub cycles: u64,
    /// (instructions, cycles) per window, for phase-level CPI curves
    /// (Figure 6). Windows follow original trace order.
    pub windows: Vec<(u64, u64)>,
    /// Wall-clock seconds spent simulating (excludes artifact compile).
    pub wall_seconds: f64,
    /// Total predictor invocations (= instructions simulated).
    pub inferences: u64,
}

impl SimOutcome {
    pub fn cpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.cycles as f64 / self.instructions as f64
        }
    }

    /// Simulation throughput in million instructions per second.
    pub fn mips(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.instructions as f64 / self.wall_seconds / 1e6
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{simulate, SimConfig};
    use crate::predictor::TablePredictor;
    use crate::trace::TraceRecord;
    use crate::workload::find;

    fn make_records(bench: &str, n: u64) -> (Vec<TraceRecord>, crate::des::DesStats) {
        let cfg = SimConfig::default_o3();
        let b = find(bench).unwrap();
        let mut recs = Vec::new();
        let stats = simulate(&cfg, b.workload(0).stream(), n, |e| {
            recs.push(TraceRecord::from(e));
        });
        (recs, stats)
    }

    /// An "oracle" run: feed the DES ground-truth latencies through the
    /// simulator loop. This validates Eq. 1 end-to-end: with perfect
    /// latency predictions the ML simulator must land within the drain
    /// slack of the DES cycle count.
    #[test]
    fn oracle_latencies_reproduce_des_cycles() {
        let cfg = SimConfig::default_o3();
        let (recs, stats) = make_records("gcc", 20_000);
        let mut tracker = crate::features::ContextTracker::new(&cfg);
        for r in &recs {
            tracker.push(&r.inst, &r.hist, r.f_lat, r.e_lat, r.s_lat);
        }
        let cycles = tracker.cur_tick + tracker.drain();
        let ratio = cycles as f64 / stats.cycles as f64;
        assert!(
            (0.95..=1.05).contains(&ratio),
            "oracle replay off: {cycles} vs {} (ratio {ratio:.3})",
            stats.cycles
        );
    }

    #[test]
    fn sequential_runs_and_is_deterministic() {
        let cfg = SimConfig::default_o3();
        let (recs, _) = make_records("namd", 5_000);
        let mut p1 = TablePredictor::new(16);
        let a = simulate_sequential(&recs, &cfg, &mut p1, 1000).unwrap();
        let mut p2 = TablePredictor::new(16);
        let b = simulate_sequential(&recs, &cfg, &mut p2, 1000).unwrap();
        assert_eq!(a.instructions, 5_000);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.windows.len(), 5);
        assert!(a.cpi() > 0.1 && a.cpi() < 100.0, "cpi={}", a.cpi());
    }

    #[test]
    fn parallel_matches_sequential_on_large_subtraces() {
        // With 1 sub-trace, parallel must equal sequential exactly.
        let cfg = SimConfig::default_o3();
        let (recs, _) = make_records("leela", 4_000);
        let mut p1 = TablePredictor::new(16);
        let seq = simulate_sequential(&recs, &cfg, &mut p1, 0).unwrap();
        let mut p2 = TablePredictor::new(16);
        let one = ParallelOptions::default();
        let par1 = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p2, &one).unwrap();
        assert_eq!(seq.cycles, par1.cycles);
        // With several sub-traces the totals differ only by boundary
        // effects (cold context at each sub-trace start).
        let mut p4 = TablePredictor::new(16);
        let four = ParallelOptions { subtraces: 4, ..ParallelOptions::default() };
        let par4 = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p4, &four).unwrap();
        assert_eq!(par4.instructions, 4_000);
        let ratio = par4.cycles as f64 / seq.cycles as f64;
        assert!((0.8..=1.25).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn parallel_subtrace_count_exceeding_len_clamps() {
        let cfg = SimConfig::default_o3();
        let (recs, _) = make_records("xz", 100);
        let mut p = TablePredictor::new(16);
        let opts = ParallelOptions { subtraces: 1000, ..ParallelOptions::default() };
        let out = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p, &opts).unwrap();
        assert_eq!(out.instructions, 100);
    }

    #[test]
    fn windows_partition_instructions() {
        let cfg = SimConfig::default_o3();
        let (recs, _) = make_records("mcf", 7_500);
        let mut p = TablePredictor::new(16);
        let out = simulate_sequential(&recs, &cfg, &mut p, 2000).unwrap();
        let total: u64 = out.windows.iter().map(|(n, _)| n).sum();
        assert_eq!(total, 7_500);
        let cyc: u64 = out.windows.iter().map(|(_, c)| c).sum();
        // Window cycles exclude the final drain only.
        assert!(cyc <= out.cycles && out.cycles - cyc < 100_000);
    }
}
