//! Multi-worker orchestration — the paper's multi-GPU scaling (§3.3,
//! Figure 9) mapped onto worker threads.
//!
//! Sub-traces are sharded across `workers` OS threads. Each worker owns a
//! private predictor instance (its own compiled PJRT executable — one
//! "device stream"), so no cross-worker communication happens during
//! simulation, mirroring the paper's "no inter-GPU communication is
//! required" property. Results are reduced at the end.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::des::SimConfig;
use crate::predictor::{LatencyPredictor, MlPredictor, TablePredictor};
use crate::trace::TraceRecord;

use super::parallel::simulate_parallel;
use super::SimOutcome;

/// How each worker constructs its predictor.
#[derive(Debug, Clone)]
pub enum PoolPredictor {
    /// Load the AOT model from the artifacts dir (one PJRT stream per
    /// worker). (artifacts, model, optional weights file)
    Ml { artifacts: PathBuf, model: String, weights: Option<PathBuf> },
    /// Analytical table predictor (tests / ablation).
    Table { seq: usize },
}

/// Options for a pooled run.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    pub workers: usize,
    /// Total sub-traces across all workers.
    pub subtraces: usize,
    pub predictor: PoolPredictor,
    /// CPI window (0 = none).
    pub window: u64,
}

/// Shard the trace over a worker pool; each worker runs sub-trace-parallel
/// simulation over its shard. Returns the merged outcome (wall time is the
/// max over workers — they run concurrently).
pub fn simulate_pool(records: &[TraceRecord], cfg: &SimConfig, opts: &PoolOptions) -> Result<SimOutcome> {
    let workers = opts.workers.max(1);
    let n = records.len();
    let shard = n.div_ceil(workers);
    let sub_per_worker = (opts.subtraces / workers).max(1);
    let t0 = Instant::now();

    let results: Vec<Result<SimOutcome>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let lo = (w * shard).min(n);
            let hi = ((w + 1) * shard).min(n);
            let slice = &records[lo..hi];
            let opts = opts.clone();
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || -> Result<SimOutcome> {
                if slice.is_empty() {
                    return Ok(SimOutcome::default());
                }
                let mut predictor: Box<dyn LatencyPredictor> = match &opts.predictor {
                    PoolPredictor::Ml { artifacts, model, weights } => Box::new(
                        MlPredictor::load(artifacts, model, weights.as_deref())?,
                    ),
                    PoolPredictor::Table { seq } => Box::new(TablePredictor::new(*seq)),
                };
                simulate_parallel(slice, &cfg, predictor.as_mut(), sub_per_worker, opts.window)
            }));
        }
        handles.into_iter().map(|h| h.join().map_err(|_| anyhow!("worker panicked"))?).map(Ok)
            .collect::<Vec<_>>()
            .into_iter()
            .map(|r| r.and_then(|x| x))
            .collect()
    });

    let mut merged = SimOutcome::default();
    for r in results {
        let r = r?;
        merged.instructions += r.instructions;
        merged.cycles += r.cycles;
        merged.inferences += r.inferences;
        merged.windows.extend(r.windows);
    }
    merged.wall_seconds = t0.elapsed().as_secs_f64();
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::simulate;
    use crate::workload::find;

    #[test]
    fn pool_with_table_predictor_scales_shards() {
        let cfg = SimConfig::default_o3();
        let b = find("povray").unwrap();
        let mut recs = Vec::new();
        simulate(&cfg, b.workload(0).stream(), 6_000, |e| recs.push(TraceRecord::from(e)));
        let opts = PoolOptions {
            workers: 3,
            subtraces: 12,
            predictor: PoolPredictor::Table { seq: 16 },
            window: 0,
        };
        let out = simulate_pool(&recs, &cfg, &opts).unwrap();
        assert_eq!(out.instructions, 6_000);
        assert!(out.cycles > 0);
        // Same totals as a single-worker run with the same sub-trace count
        // per shard boundary structure is not guaranteed, but the CPI must
        // be in the same ballpark.
        let one = simulate_pool(
            &recs,
            &cfg,
            &PoolOptions {
                workers: 1,
                subtraces: 12,
                predictor: PoolPredictor::Table { seq: 16 },
                window: 0,
            },
        )
        .unwrap();
        let ratio = out.cpi() / one.cpi();
        assert!((0.8..1.25).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn pool_handles_more_workers_than_records() {
        let cfg = SimConfig::default_o3();
        let b = find("nab").unwrap();
        let mut recs = Vec::new();
        simulate(&cfg, b.workload(0).stream(), 10, |e| recs.push(TraceRecord::from(e)));
        let opts = PoolOptions {
            workers: 8,
            subtraces: 8,
            predictor: PoolPredictor::Table { seq: 8 },
            window: 0,
        };
        let out = simulate_pool(&recs, &cfg, &opts).unwrap();
        assert_eq!(out.instructions, 10);
    }
}
