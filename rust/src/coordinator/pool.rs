//! Multi-job pooling — the paper's multi-GPU scaling (§3.3, Figure 9)
//! mapped onto the shared [`BatchEngine`].
//!
//! The trace is sharded into `workers` contiguous slices, but unlike the
//! seed implementation (one OS thread + one private predictor + private
//! batches per worker), every shard is submitted as a job to ONE engine
//! driven by ONE parent predictor: the next-instruction slots of all
//! shards' sub-traces are multiplexed into common accelerator batches.
//! At equal total sub-trace count this sustains far higher
//! predictor-batch occupancy than per-worker pooling (see
//! `benches/bench_engine.rs`), which is what DL-based simulators live or
//! die on. When the engine runs multi-threaded and the predictor
//! supports [`LatencyPredictor::fork`], each encode worker gets its own
//! forked handle over the shared model (see
//! [`EngineOptions::fork_predict`]) — the pool's deliberate design point
//! is shared *batching*, never serializing shards on one predictor's
//! scratch buffers.
//!
//! The requested sub-trace total is distributed across shards with its
//! remainder (12 sub-traces over 8 workers yields 12, not 8 — the seed
//! silently dropped the remainder).
//!
//! The predictor is supplied by the caller (built from an
//! [`crate::api::PredictorSpec`] by [`crate::api::Simulation`], which is
//! how every CLI/report/bench run reaches this module).

use std::time::Instant;

use anyhow::Result;

use crate::des::SimConfig;
use crate::predictor::LatencyPredictor;
use crate::trace::{RecordsView, TraceRecord};

use super::engine::{BatchEngine, EngineOptions, EngineStats, JobSpec};
use super::SimOutcome;

/// Options for a pooled run (the predictor is passed separately so one
/// predictor can serve many pooled runs).
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Shards (jobs) the trace is split into.
    pub workers: usize,
    /// Total sub-traces across all workers.
    pub subtraces: usize,
    /// CPI window (0 = none).
    pub window: u64,
    /// Configuration input feature applied to every shard (§5 ROB
    /// study), 0.0 when unused.
    pub cfg_feature: f32,
    /// Shared-engine execution knobs (target batch, encode threads,
    /// pipeline depth).
    pub engine: EngineOptions,
    /// Shared progress counter bumped once per simulated instruction
    /// across every shard (see [`JobSpec::progress`]); `None` costs
    /// nothing.
    pub progress: Option<std::sync::Arc<std::sync::atomic::AtomicU64>>,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            workers: 1,
            subtraces: 1,
            window: 0,
            cfg_feature: 0.0,
            engine: EngineOptions::default(),
            progress: None,
        }
    }
}

/// Shard the trace over `workers` jobs of one shared [`BatchEngine`];
/// returns the merged outcome.
pub fn simulate_pool(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    opts: &PoolOptions,
) -> Result<SimOutcome> {
    let (out, _) = simulate_pool_report(records, cfg, predictor, opts)?;
    Ok(out)
}

/// [`simulate_pool`] returning the engine's batching statistics as well.
pub fn simulate_pool_report(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    opts: &PoolOptions,
) -> Result<(SimOutcome, EngineStats)> {
    simulate_pool_view(records.into(), cfg, predictor, opts)
}

/// The streaming-capable core behind [`simulate_pool_report`]: shards a
/// [`RecordsView`] (decoded slice or mapped streaming view) over the
/// engine's jobs. Each shard's sub-traces read through their own bounded
/// cursors, so a mapped trace never materializes in full.
pub fn simulate_pool_view(
    records: RecordsView<'_>,
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    opts: &PoolOptions,
) -> Result<(SimOutcome, EngineStats)> {
    let workers = opts.workers.max(1);
    let n = records.len();
    let shard = n.div_ceil(workers).max(1);
    let t0 = Instant::now();

    let mut engine = BatchEngine::with_options(predictor, opts.engine);

    // Distribute the requested sub-trace total across the NON-EMPTY
    // shards (with fewer records than workers, trailing shards are
    // empty and must not swallow their sub-trace allotment), spreading
    // the remainder over the leading shards. The engine still clamps
    // each job to its record count, so physically impossible requests
    // degrade gracefully.
    let nshards = if n == 0 { 0 } else { n.div_ceil(shard).min(workers) };
    let base = if nshards == 0 { 0 } else { opts.subtraces / nshards };
    let rem = if nshards == 0 { 0 } else { opts.subtraces % nshards };
    for w in 0..nshards {
        let lo = (w * shard).min(n);
        let hi = ((w + 1) * shard).min(n);
        if lo >= hi {
            continue;
        }
        let subtraces = (base + usize::from(w < rem)).max(1);
        engine.submit(JobSpec {
            records: records.slice(lo, hi),
            cfg,
            subtraces,
            window: opts.window,
            cfg_feature: opts.cfg_feature,
            progress: opts.progress.clone(),
        });
    }

    let report = engine.run()?;
    let stats = report.stats.clone();
    let mut merged = report.merged();
    merged.wall_seconds = t0.elapsed().as_secs_f64();
    Ok((merged, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::simulate;
    use crate::predictor::TablePredictor;
    use crate::workload::find;

    fn records(bench: &str, n: u64) -> (Vec<TraceRecord>, SimConfig) {
        let cfg = SimConfig::default_o3();
        let b = find(bench).unwrap();
        let mut recs = Vec::new();
        simulate(&cfg, b.workload(0).stream(), n, |e| recs.push(TraceRecord::from(e)));
        (recs, cfg)
    }

    fn table_opts(workers: usize, subtraces: usize) -> PoolOptions {
        PoolOptions {
            workers,
            subtraces,
            window: 0,
            cfg_feature: 0.0,
            engine: EngineOptions {
                target_batch: 0,
                encode_threads: 1,
                pipeline_depth: 1,
                fork_predict: true,
            },
            progress: None,
        }
    }

    fn run(
        recs: &[TraceRecord],
        cfg: &SimConfig,
        seq: usize,
        opts: &PoolOptions,
    ) -> (SimOutcome, EngineStats) {
        let mut p = TablePredictor::new(seq);
        simulate_pool_report(recs, cfg, &mut p, opts).unwrap()
    }

    #[test]
    fn pool_with_table_predictor_scales_shards() {
        let (recs, cfg) = records("povray", 6_000);
        let (out, _) = run(&recs, &cfg, 16, &table_opts(3, 12));
        assert_eq!(out.instructions, 6_000);
        assert!(out.cycles > 0);
        // Shard boundary structure differs from a single-worker run, but
        // the CPI must be in the same ballpark.
        let (one, _) = run(&recs, &cfg, 16, &table_opts(1, 12));
        let ratio = out.cpi() / one.cpi();
        assert!((0.8..1.25).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn pool_handles_more_workers_than_records() {
        // 10 records over 8 workers -> 5 non-empty 2-record shards; the
        // 8 requested sub-traces must be redistributed over those 5
        // shards (2+2+2+1+1), not dropped with the empty ones.
        let (recs, cfg) = records("nab", 10);
        let (out, stats) = run(&recs, &cfg, 8, &table_opts(8, 8));
        assert_eq!(out.instructions, 10);
        assert_eq!(stats.subtraces, 8);
    }

    #[test]
    fn pool_distributes_subtrace_remainder() {
        // The seed computed (subtraces / workers).max(1) per worker: 12
        // sub-traces over 8 workers silently became 8. The engine must
        // create all 12.
        let (recs, cfg) = records("gcc", 6_000);
        let (out, stats) = run(&recs, &cfg, 16, &table_opts(8, 12));
        assert_eq!(out.instructions, 6_000);
        assert_eq!(stats.subtraces, 12);
        // Exact division still works.
        let (_, stats) = run(&recs, &cfg, 16, &table_opts(4, 12));
        assert_eq!(stats.subtraces, 12);
    }

    #[test]
    fn pool_shares_one_predictor_across_jobs() {
        // All shards' slots must flow through the one shared engine:
        // total batch slots == total instructions, and with an unbounded
        // target every full round spans every active sub-trace.
        let (recs, cfg) = records("xz", 4_000);
        let mut opts = table_opts(4, 16);
        opts.engine.target_batch = 16;
        let (out, stats) = run(&recs, &cfg, 16, &opts);
        assert_eq!(stats.slots, out.inferences);
        assert_eq!(stats.target_batch, 16);
        assert!(stats.mean_occupancy() > 8.0, "occupancy={}", stats.mean_occupancy());
    }

    #[test]
    fn pool_pipelined_matches_serial_pool_exactly() {
        // The pipelined engine behind the pool must reproduce the serial
        // pool's cycle counts, windows, and occupancy sums bit-for-bit.
        let (recs, cfg) = records("gcc", 6_000);
        let mut serial = table_opts(4, 12);
        serial.window = 500;
        let mut piped = serial.clone();
        piped.engine.encode_threads = 4;
        piped.engine.pipeline_depth = 2;
        let mut shared = piped.clone();
        shared.engine.fork_predict = false;
        let (out_s, stats_s) = run(&recs, &cfg, 16, &serial);
        // Threaded with forked per-worker handles (default) AND with the
        // shared-handle pipelined loop — both must be bit-identical.
        for opts in [&piped, &shared] {
            let (out_p, stats_p) = run(&recs, &cfg, 16, opts);
            assert_eq!(out_s.instructions, out_p.instructions);
            assert_eq!(out_s.cycles, out_p.cycles);
            assert_eq!(out_s.windows, out_p.windows);
            assert_eq!(stats_s.batches, stats_p.batches);
            assert_eq!(stats_s.slots, stats_p.slots);
            assert_eq!(stats_p.encode_threads, 4);
        }
    }

    #[test]
    fn pool_empty_trace_is_ok() {
        let (_, cfg) = records("xz", 1);
        let (out, _) = run(&[], &cfg, 16, &table_opts(4, 8));
        assert_eq!(out.instructions, 0);
        assert_eq!(out.cycles, 0);
    }
}
