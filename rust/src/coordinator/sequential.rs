//! Sequential ML simulation (paper §3.2).
//!
//! One instruction at a time: encode (current + context) → predict
//! (F, E, S) → push into the context queues → `curTick += F`. The final
//! drain adds the paper's `Delta` from Eq. 1.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::des::SimConfig;
use crate::features::{ContextTracker, NUM_FEATURES};
use crate::predictor::LatencyPredictor;
use crate::trace::{RecordsView, TraceRecord};

use super::SimOutcome;

/// Simulate `records` sequentially with `predictor`. `window` > 0 emits a
/// CPI series entry every `window` instructions (Figure 6).
pub fn simulate_sequential(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    window: u64,
) -> Result<SimOutcome> {
    simulate_sequential_view(records.into(), cfg, predictor, window, None)
}

/// [`simulate_sequential`] that additionally bumps `progress` once per
/// simulated instruction (relaxed ordering) — the job server's streaming
/// progress hook. Results are identical to the plain entry point.
pub fn simulate_sequential_progress(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    window: u64,
    progress: Option<&AtomicU64>,
) -> Result<SimOutcome> {
    simulate_sequential_view(records.into(), cfg, predictor, window, progress)
}

/// The streaming-capable core behind both entry points: drives a
/// [`RecordsView`] through a single forward [`crate::trace::RecordCursor`],
/// so a mapped trace is simulated with a bounded decode window instead of
/// a full in-memory copy. Over a plain slice the cursor is a zero-cost
/// passthrough and the loop is byte-identical to the historical one.
pub fn simulate_sequential_view(
    records: RecordsView<'_>,
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    window: u64,
    progress: Option<&AtomicU64>,
) -> Result<SimOutcome> {
    let seq = predictor.seq_len();
    let mut tracker = ContextTracker::with_mode(cfg, predictor.context_mode());
    let mut buf = vec![0.0f32; seq * NUM_FEATURES];
    let mut out = SimOutcome::default();
    let mut window_insts = 0u64;
    let mut window_start_tick = 0u64;
    let t0 = Instant::now();

    let mut cur = records.cursor();
    for i in 0..cur.len() {
        let rec = cur.get(i);
        tracker.encode_input(&rec.inst, &rec.hist, seq, &mut buf);
        let (f, e, s) = predictor.predict(&buf, 1)?[0];
        // Stores must have a store latency at least covering execution;
        // non-stores must not linger in the memory write queue.
        let s = if rec.inst.is_store() { s.max(e + 1) } else { 0 };
        tracker.push(&rec.inst, &rec.hist, f, e.max(1), s);
        out.instructions += 1;
        if let Some(p) = progress {
            p.fetch_add(1, Ordering::Relaxed);
        }
        window_insts += 1;
        if window > 0 && window_insts == window {
            out.windows.push((window_insts, tracker.cur_tick - window_start_tick));
            window_start_tick = tracker.cur_tick;
            window_insts = 0;
        }
    }
    if window > 0 && window_insts > 0 {
        out.windows.push((window_insts, tracker.cur_tick - window_start_tick));
    }
    let drain = tracker.drain();
    out.cycles = tracker.cur_tick;
    let _ = drain;
    out.inferences = out.instructions;
    out.wall_seconds = t0.elapsed().as_secs_f64();
    Ok(out)
}
