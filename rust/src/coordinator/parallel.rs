//! Sub-trace parallel ML simulation (paper §3.3, Figure 4).
//!
//! The input trace is partitioned into `subtraces` equally sized
//! *contiguous* sub-traces. Each sub-trace is simulated sequentially
//! against its own context queues and clock, but every simulation step
//! gathers the next instruction of all still-active sub-traces into ONE
//! batched predictor call — this is what turns the inherently sequential
//! prediction chain into accelerator-sized batches. Total time is the sum
//! of the per-sub-trace clocks; the loss of cross-boundary context is the
//! accuracy cost Figure 7 studies.
//!
//! Since the [`super::engine`] refactor this module is a thin single-job
//! wrapper over [`BatchEngine`] (unbounded target batch = the original
//! one-batch-per-round behavior, serial encode path). The entry point is
//! [`simulate_parallel_with`], which takes a [`ParallelOptions`] struct
//! and a streaming-capable [`RecordsView`]; the historical positional
//! signatures (`simulate_parallel`, `simulate_parallel_cfg`) remain as
//! deprecated shims. Use [`BatchEngine::with_options`] directly for the
//! pipelined multi-threaded configuration.

use anyhow::Result;

use crate::des::SimConfig;
use crate::predictor::LatencyPredictor;
use crate::trace::{RecordsView, TraceRecord};

use super::engine::{BatchEngine, JobSpec};
use super::SimOutcome;

/// Knobs for [`simulate_parallel_with`] — the collapsed form of the old
/// `simulate_parallel` / `simulate_parallel_cfg` positional signatures.
///
/// # Examples
///
/// ```
/// use simnet::coordinator::ParallelOptions;
///
/// let opts = ParallelOptions { subtraces: 16, ..ParallelOptions::default() };
/// assert_eq!(opts.window, 0); // no CPI series by default
/// assert_eq!(opts.cfg_feature, 0.0); // §5 ROB study feature off
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParallelOptions {
    /// Sub-trace parallelism (clamped to the trace size; 1 = sequential
    /// batching semantics through the engine).
    pub subtraces: usize,
    /// CPI window in instructions (0 = no windows), Figure 6.
    pub window: u64,
    /// Configuration input feature on every context tracker (the §5 ROB
    /// study feeds the ROB size here), 0.0 when unused.
    pub cfg_feature: f32,
}

impl Default for ParallelOptions {
    fn default() -> Self {
        ParallelOptions { subtraces: 1, window: 0, cfg_feature: 0.0 }
    }
}

/// Simulate one record view with sub-trace parallelism per `opts`.
///
/// Accepts any [`RecordsView`] — a decoded slice (`(&recs[..]).into()`)
/// or a streaming view of a mapped trace (`store.view()`), in which case
/// each sub-trace decodes through a bounded window instead of a full
/// in-memory copy. Results are bit-identical either way.
pub fn simulate_parallel_with(
    records: RecordsView<'_>,
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    opts: &ParallelOptions,
) -> Result<SimOutcome> {
    let mut engine = BatchEngine::new(predictor, 0);
    engine.submit(JobSpec {
        records,
        cfg,
        subtraces: opts.subtraces,
        window: opts.window,
        cfg_feature: opts.cfg_feature,
        progress: None,
    });
    let report = engine.run()?;
    Ok(report.merged())
}

/// Simulate with `num_subtraces`-way sub-trace parallelism. `window` > 0
/// emits CPI-series windows (in original trace order).
#[deprecated(note = "use `simulate_parallel_with` and `ParallelOptions`")]
pub fn simulate_parallel(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    num_subtraces: usize,
    window: u64,
) -> Result<SimOutcome> {
    let opts = ParallelOptions { subtraces: num_subtraces, window, cfg_feature: 0.0 };
    simulate_parallel_with(records.into(), cfg, predictor, &opts)
}

/// `simulate_parallel` with the configuration feature set on every
/// context tracker (the §5 ROB study feeds the ROB size here).
#[deprecated(note = "use `simulate_parallel_with` and `ParallelOptions`")]
pub fn simulate_parallel_cfg(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    num_subtraces: usize,
    window: u64,
    cfg_feature: f32,
) -> Result<SimOutcome> {
    let opts = ParallelOptions { subtraces: num_subtraces, window, cfg_feature };
    simulate_parallel_with(records.into(), cfg, predictor, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::simulate;
    use crate::predictor::TablePredictor;
    use crate::workload::find;

    /// The deprecated positional shims must stay exact aliases of the
    /// options-struct entry point.
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_the_options_entry_point() {
        let cfg = SimConfig::default_o3();
        let b = find("xz").unwrap();
        let mut recs = Vec::new();
        simulate(&cfg, b.workload(0).stream(), 2_000, |e| recs.push(TraceRecord::from(e)));

        let mut p1 = TablePredictor::new(16);
        let opts = ParallelOptions { subtraces: 4, window: 500, cfg_feature: 2.5 };
        let new = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p1, &opts).unwrap();

        let mut p2 = TablePredictor::new(16);
        let old = simulate_parallel_cfg(&recs, &cfg, &mut p2, 4, 500, 2.5).unwrap();
        assert_eq!(new.cycles, old.cycles);
        assert_eq!(new.instructions, old.instructions);
        assert_eq!(new.windows, old.windows);

        let mut p3 = TablePredictor::new(16);
        let plain_opts = ParallelOptions { subtraces: 4, window: 500, ..Default::default() };
        let plain = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p3, &plain_opts).unwrap();
        let mut p4 = TablePredictor::new(16);
        let old_plain = simulate_parallel(&recs, &cfg, &mut p4, 4, 500).unwrap();
        assert_eq!(plain.cycles, old_plain.cycles);
        assert_eq!(plain.windows, old_plain.windows);
    }
}
