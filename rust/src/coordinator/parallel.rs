//! Sub-trace parallel ML simulation (paper §3.3, Figure 4).
//!
//! The input trace is partitioned into `num_subtraces` equally sized
//! *contiguous* sub-traces. Each sub-trace is simulated sequentially
//! against its own context queues and clock, but every simulation step
//! gathers the next instruction of all still-active sub-traces into ONE
//! batched predictor call — this is what turns the inherently sequential
//! prediction chain into accelerator-sized batches. Total time is the sum
//! of the per-sub-trace clocks; the loss of cross-boundary context is the
//! accuracy cost Figure 7 studies.
//!
//! Since the [`super::engine`] refactor this module is a thin single-job
//! wrapper over [`BatchEngine`] (unbounded target batch = the original
//! one-batch-per-round behavior, serial encode path), kept for backward
//! compatibility; use [`BatchEngine::with_options`] directly for the
//! pipelined multi-threaded configuration.

use anyhow::Result;

use crate::des::SimConfig;
use crate::predictor::LatencyPredictor;
use crate::trace::TraceRecord;

use super::engine::{BatchEngine, JobSpec};
use super::SimOutcome;

/// Simulate with `num_subtraces`-way sub-trace parallelism. `window` > 0
/// emits CPI-series windows (in original trace order).
pub fn simulate_parallel(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    num_subtraces: usize,
    window: u64,
) -> Result<SimOutcome> {
    simulate_parallel_cfg(records, cfg, predictor, num_subtraces, window, 0.0)
}

/// [`simulate_parallel`] with the configuration feature set on every
/// context tracker (the §5 ROB study feeds the ROB size here).
pub fn simulate_parallel_cfg(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    num_subtraces: usize,
    window: u64,
    cfg_feature: f32,
) -> Result<SimOutcome> {
    let mut engine = BatchEngine::new(predictor, 0);
    engine.submit(JobSpec {
        records,
        cfg,
        subtraces: num_subtraces,
        window,
        cfg_feature,
        progress: None,
    });
    let report = engine.run()?;
    Ok(report.merged())
}
