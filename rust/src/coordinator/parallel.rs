//! Sub-trace parallel ML simulation (paper §3.3, Figure 4).
//!
//! The input trace is partitioned into `num_subtraces` equally sized
//! *contiguous* sub-traces. Each sub-trace is simulated sequentially
//! against its own context queues and clock, but every simulation step
//! gathers the next instruction of all still-active sub-traces into ONE
//! batched predictor call — this is what turns the inherently sequential
//! prediction chain into accelerator-sized batches. Total time is the sum
//! of the per-sub-trace clocks; the loss of cross-boundary context is the
//! accuracy cost Figure 7 studies.

use std::time::Instant;

use anyhow::Result;

use crate::des::SimConfig;
use crate::features::{ContextTracker, NUM_FEATURES};
use crate::predictor::LatencyPredictor;
use crate::trace::TraceRecord;

use super::SimOutcome;

struct SubTrace<'a> {
    records: &'a [TraceRecord],
    pos: usize,
    tracker: ContextTracker,
    /// Windowed CPI bookkeeping (concatenated in trace order afterwards).
    windows: Vec<(u64, u64)>,
    window_insts: u64,
    window_start: u64,
}

/// Simulate with `num_subtraces`-way sub-trace parallelism. `window` > 0
/// emits CPI-series windows (in original trace order).
pub fn simulate_parallel(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    num_subtraces: usize,
    window: u64,
) -> Result<SimOutcome> {
    simulate_parallel_cfg(records, cfg, predictor, num_subtraces, window, 0.0)
}

/// [`simulate_parallel`] with the configuration feature set on every
/// context tracker (the §5 ROB study feeds the ROB size here).
pub fn simulate_parallel_cfg(
    records: &[TraceRecord],
    cfg: &SimConfig,
    predictor: &mut dyn LatencyPredictor,
    num_subtraces: usize,
    window: u64,
    cfg_feature: f32,
) -> Result<SimOutcome> {
    let n = records.len();
    let s = num_subtraces.clamp(1, n.max(1));
    let chunk = n.div_ceil(s);
    let seq = predictor.seq_len();
    let width = seq * NUM_FEATURES;
    let mode = predictor.context_mode();

    let mut subs: Vec<SubTrace> = records
        .chunks(chunk)
        .map(|c| {
            let mut tracker = ContextTracker::with_mode(cfg, mode);
            tracker.cfg_feature = cfg_feature;
            SubTrace {
            records: c,
            pos: 0,
            tracker,
            windows: Vec::new(),
            window_insts: 0,
            window_start: 0,
        }})
        .collect();

    let mut batch = vec![0.0f32; subs.len() * width];
    let mut active: Vec<usize> = (0..subs.len()).collect();
    let mut out = SimOutcome::default();
    let t0 = Instant::now();

    while !active.is_empty() {
        // Gather: encode the next instruction of every active sub-trace.
        for (k, &si) in active.iter().enumerate() {
            let sub = &subs[si];
            let rec = &sub.records[sub.pos];
            sub.tracker.encode_input(
                &rec.inst,
                &rec.hist,
                seq,
                &mut batch[k * width..(k + 1) * width],
            );
        }
        // One batched inference across sub-traces.
        let preds = predictor.predict(&batch, active.len())?;
        // Scatter: apply predictions, advance cursors.
        for (k, &si) in active.iter().enumerate() {
            let sub = &mut subs[si];
            let rec = &sub.records[sub.pos];
            let (f, e, s_lat) = preds[k];
            let s_lat = if rec.inst.is_store() { s_lat.max(e + 1) } else { 0 };
            sub.tracker.push(&rec.inst, &rec.hist, f, e.max(1), s_lat);
            sub.pos += 1;
            out.instructions += 1;
            sub.window_insts += 1;
            if window > 0 && sub.window_insts == window {
                sub.windows.push((sub.window_insts, sub.tracker.cur_tick - sub.window_start));
                sub.window_start = sub.tracker.cur_tick;
                sub.window_insts = 0;
            }
        }
        active.retain(|&si| subs[si].pos < subs[si].records.len());
    }

    // Total cycles = sum of per-sub-trace clocks (paper: "we sum up their
    // curTicks to get the total execution time").
    for sub in &mut subs {
        if window > 0 && sub.window_insts > 0 {
            sub.windows.push((sub.window_insts, sub.tracker.cur_tick - sub.window_start));
        }
        sub.tracker.drain();
        out.cycles += sub.tracker.cur_tick;
        out.windows.extend(sub.windows.drain(..));
    }
    out.inferences = out.instructions;
    out.wall_seconds = t0.elapsed().as_secs_f64();
    Ok(out)
}
