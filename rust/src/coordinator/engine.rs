//! Shared dynamic-batching simulation engine (paper §3.3, Figures 4/9).
//!
//! SimNet's throughput comes entirely from turning the inherently
//! sequential prediction chain into accelerator-sized batches: §3.3
//! splits one trace into sub-traces and batches their per-step
//! predictions (Figure 4), and Figure 9 scales that across devices by
//! sharding sub-traces over workers. The seed implementation capped the
//! batch at one worker's private sub-trace count — each pool worker
//! owned its own predictor, so batches never crossed worker or job
//! boundaries and predictor occupancy collapsed as workers grew.
//!
//! [`BatchEngine`] inverts that: a job-queue front end accepts many
//! concurrent simulation jobs ([`JobSpec`]: trace slice + `SimConfig` +
//! config feature), and the scheduler multiplexes the next-instruction
//! slots of *all* active sub-traces across *all* jobs into shared
//! [`LatencyPredictor`] batches with a configurable target batch size.
//! This is the software analogue of the paper's multi-GPU claim ("no
//! inter-device communication is required"): sub-traces only meet inside
//! a predictor batch, so scheduling order cannot change any job's
//! result — each prediction depends only on that sub-trace's own context
//! queue. Results are demuxed deterministically back to each job's
//! `ContextTracker`s and CPI windows, and per-batch occupancy /
//! starvation counters ([`EngineStats`]) expose how full the
//! accelerator batches actually ran — the quantity Figures 8/9 sweep.
//!
//! One simulation round advances every active sub-trace by exactly one
//! instruction: slots are gathered in deterministic (job, sub-trace)
//! submission order, chunked to the target batch size, predicted, and
//! scattered back. Total cycles per job remain the sum of its sub-trace
//! `curTick`s plus drain (Eq. 1), exactly as in [`super::parallel`].

use std::time::Instant;

use anyhow::Result;

use crate::des::SimConfig;
use crate::features::{ContextTracker, NUM_FEATURES};
use crate::predictor::LatencyPredictor;
use crate::trace::TraceRecord;

use super::SimOutcome;

/// One simulation job submitted to the engine.
pub struct JobSpec<'a> {
    /// Trace slice to simulate (contiguous instruction records).
    pub records: &'a [TraceRecord],
    /// Machine configuration for the job's context trackers.
    pub cfg: &'a SimConfig,
    /// Sub-trace parallelism within the job (clamped to the trace size).
    pub subtraces: usize,
    /// CPI window in instructions (0 = no windows).
    pub window: u64,
    /// Configuration input feature (§5 ROB study), 0.0 when unused.
    pub cfg_feature: f32,
}

/// Per-run predictor-batch statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Predictor calls issued.
    pub batches: u64,
    /// Total filled slots across all batches (== total inferences).
    pub slots: u64,
    /// Effective batch-size target (configured target, or the initial
    /// active sub-trace count when running unbounded).
    pub target_batch: usize,
    /// Batches that went out with fewer slots than the target.
    pub starved: u64,
    /// Sub-traces created across all jobs.
    pub subtraces: u64,
}

impl EngineStats {
    /// Mean filled slots per predictor call.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.slots as f64 / self.batches as f64
        }
    }

    /// Mean batch fill as a fraction of the target batch size.
    pub fn fill_ratio(&self) -> f64 {
        if self.target_batch == 0 {
            0.0
        } else {
            self.mean_occupancy() / self.target_batch as f64
        }
    }
}

/// Outcome of an engine run: one [`SimOutcome`] per job (submission
/// order) plus shared batching statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineReport {
    pub jobs: Vec<SimOutcome>,
    pub stats: EngineStats,
    pub wall_seconds: f64,
}

impl EngineReport {
    /// Merge all per-job outcomes into one (window lists concatenate in
    /// job submission order; wall time is the shared engine wall time).
    pub fn merged(self) -> SimOutcome {
        let wall = self.wall_seconds;
        let mut merged = SimOutcome::default();
        for job in self.jobs {
            merged.instructions += job.instructions;
            merged.cycles += job.cycles;
            merged.inferences += job.inferences;
            merged.windows.extend(job.windows);
        }
        merged.wall_seconds = wall;
        merged
    }
}

struct SubTrace<'a> {
    records: &'a [TraceRecord],
    pos: usize,
    tracker: ContextTracker,
    windows: Vec<(u64, u64)>,
    window_insts: u64,
    window_start: u64,
}

struct JobState<'a> {
    subs: Vec<SubTrace<'a>>,
    window: u64,
    outcome: SimOutcome,
}

/// Multi-job shared-batch simulation engine. Construct with a predictor
/// and a target batch size (0 = one batch per round over every active
/// sub-trace), [`submit`](Self::submit) any number of jobs, then
/// [`run`](Self::run).
pub struct BatchEngine<'a, 'p> {
    predictor: &'p mut dyn LatencyPredictor,
    target_batch: usize,
    seq: usize,
    width: usize,
    jobs: Vec<JobState<'a>>,
}

impl<'a, 'p> BatchEngine<'a, 'p> {
    pub fn new(predictor: &'p mut dyn LatencyPredictor, target_batch: usize) -> Self {
        let seq = predictor.seq_len();
        BatchEngine { predictor, target_batch, seq, width: seq * NUM_FEATURES, jobs: Vec::new() }
    }

    /// Queue a job; returns its index into [`EngineReport::jobs`].
    pub fn submit(&mut self, spec: JobSpec<'a>) -> usize {
        let n = spec.records.len();
        let mode = self.predictor.context_mode();
        let subs: Vec<SubTrace<'a>> = if n == 0 {
            Vec::new()
        } else {
            let s = spec.subtraces.clamp(1, n);
            let chunk = n.div_ceil(s);
            spec.records
                .chunks(chunk)
                .map(|c| {
                    let mut tracker = ContextTracker::with_mode(spec.cfg, mode);
                    tracker.cfg_feature = spec.cfg_feature;
                    SubTrace {
                        records: c,
                        pos: 0,
                        tracker,
                        windows: Vec::new(),
                        window_insts: 0,
                        window_start: 0,
                    }
                })
                .collect()
        };
        self.jobs.push(JobState { subs, window: spec.window, outcome: SimOutcome::default() });
        self.jobs.len() - 1
    }

    /// Number of jobs queued so far.
    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }

    /// Drive every queued job to completion, multiplexing all active
    /// sub-traces into shared predictor batches.
    pub fn run(mut self) -> Result<EngineReport> {
        let mut active: Vec<(usize, usize)> = Vec::new();
        for (ji, job) in self.jobs.iter().enumerate() {
            for si in 0..job.subs.len() {
                active.push((ji, si));
            }
        }
        // Clamp to the active sub-trace count: a batch can never hold
        // more slots than sub-traces, and the gather buffer is sized by
        // this (an unclamped huge --target-batch must not OOM).
        let cap = if self.target_batch == 0 {
            active.len().max(1)
        } else {
            self.target_batch.min(active.len()).max(1)
        };
        let mut stats = EngineStats {
            target_batch: cap,
            subtraces: active.len() as u64,
            ..EngineStats::default()
        };
        let mut batch = vec![0.0f32; cap * self.width];
        let t0 = Instant::now();

        while !active.is_empty() {
            // One round advances every active sub-trace by one
            // instruction, in chunks of at most `cap` slots.
            let mut base = 0;
            while base < active.len() {
                let take = cap.min(active.len() - base);
                // Gather: encode the next instruction of each slot.
                for k in 0..take {
                    let (ji, si) = active[base + k];
                    let sub = &self.jobs[ji].subs[si];
                    let rec = &sub.records[sub.pos];
                    sub.tracker.encode_input(
                        &rec.inst,
                        &rec.hist,
                        self.seq,
                        &mut batch[k * self.width..(k + 1) * self.width],
                    );
                }
                // One shared inference across jobs and sub-traces.
                let preds = self.predictor.predict(&batch[..take * self.width], take)?;
                stats.batches += 1;
                stats.slots += take as u64;
                if take < cap {
                    stats.starved += 1;
                }
                // Scatter: demux predictions back to each slot's job.
                for k in 0..take {
                    let (ji, si) = active[base + k];
                    let job = &mut self.jobs[ji];
                    let window = job.window;
                    job.outcome.instructions += 1;
                    let sub = &mut job.subs[si];
                    let rec = &sub.records[sub.pos];
                    let (f, e, s_lat) = preds[k];
                    let s_lat = if rec.inst.is_store() { s_lat.max(e + 1) } else { 0 };
                    sub.tracker.push(&rec.inst, &rec.hist, f, e.max(1), s_lat);
                    sub.pos += 1;
                    sub.window_insts += 1;
                    if window > 0 && sub.window_insts == window {
                        let cyc = sub.tracker.cur_tick - sub.window_start;
                        sub.windows.push((sub.window_insts, cyc));
                        sub.window_start = sub.tracker.cur_tick;
                        sub.window_insts = 0;
                    }
                }
                base += take;
            }
            active.retain(|&(ji, si)| {
                let sub = &self.jobs[ji].subs[si];
                sub.pos < sub.records.len()
            });
        }

        let wall = t0.elapsed().as_secs_f64();
        for job in &mut self.jobs {
            for sub in &mut job.subs {
                if job.window > 0 && sub.window_insts > 0 {
                    sub.windows.push((sub.window_insts, sub.tracker.cur_tick - sub.window_start));
                }
                sub.tracker.drain();
                // Per paper §3.3: total time is the sum of sub-trace
                // curTicks; windows concatenate in original trace order.
                job.outcome.cycles += sub.tracker.cur_tick;
                job.outcome.windows.extend(sub.windows.drain(..));
            }
            job.outcome.inferences = job.outcome.instructions;
            job.outcome.wall_seconds = wall;
        }
        Ok(EngineReport {
            jobs: self.jobs.into_iter().map(|j| j.outcome).collect(),
            stats,
            wall_seconds: wall,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::simulate_parallel;
    use crate::des::simulate;
    use crate::predictor::TablePredictor;
    use crate::workload::find;

    fn make_records(bench: &str, n: u64) -> Vec<TraceRecord> {
        let cfg = SimConfig::default_o3();
        let b = find(bench).unwrap();
        let mut recs = Vec::new();
        simulate(&cfg, b.workload(0).stream(), n, |e| recs.push(TraceRecord::from(e)));
        recs
    }

    fn job<'a>(records: &'a [TraceRecord], cfg: &'a SimConfig, subtraces: usize) -> JobSpec<'a> {
        JobSpec { records, cfg, subtraces, window: 1_000, cfg_feature: 0.0 }
    }

    #[test]
    fn single_job_engine_equals_simulate_parallel() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("gcc", 6_000);
        let mut p1 = TablePredictor::new(16);
        let par = simulate_parallel(&recs, &cfg, &mut p1, 4, 1_000).unwrap();
        let mut p2 = TablePredictor::new(16);
        let mut engine = BatchEngine::new(&mut p2, 0);
        engine.submit(job(&recs, &cfg, 4));
        let report = engine.run().unwrap();
        assert_eq!(report.jobs.len(), 1);
        let out = &report.jobs[0];
        assert_eq!(out.instructions, par.instructions);
        assert_eq!(out.cycles, par.cycles);
        assert_eq!(out.windows, par.windows);
        assert_eq!(report.stats.subtraces, 4);
    }

    #[test]
    fn submission_order_does_not_change_per_job_results() {
        let cfg = SimConfig::default_o3();
        let a = make_records("gcc", 5_000);
        let b = make_records("mcf", 4_000);
        let mut p1 = TablePredictor::new(16);
        let mut e1 = BatchEngine::new(&mut p1, 0);
        e1.submit(job(&a, &cfg, 4));
        e1.submit(job(&b, &cfg, 3));
        let r1 = e1.run().unwrap();
        let mut p2 = TablePredictor::new(16);
        let mut e2 = BatchEngine::new(&mut p2, 0);
        e2.submit(job(&b, &cfg, 3));
        e2.submit(job(&a, &cfg, 4));
        let r2 = e2.run().unwrap();
        // Per-job results must be identical regardless of submission order.
        assert_eq!(r1.jobs[0].cycles, r2.jobs[1].cycles);
        assert_eq!(r1.jobs[0].windows, r2.jobs[1].windows);
        assert_eq!(r1.jobs[1].cycles, r2.jobs[0].cycles);
        assert_eq!(r1.jobs[1].windows, r2.jobs[0].windows);
        assert_eq!(r1.stats.subtraces, r2.stats.subtraces);
    }

    #[test]
    fn occupancy_slots_sum_to_total_inferences() {
        let cfg = SimConfig::default_o3();
        let a = make_records("leela", 3_000);
        let b = make_records("xz", 2_000);
        let mut p = TablePredictor::new(16);
        let mut engine = BatchEngine::new(&mut p, 8);
        engine.submit(job(&a, &cfg, 5));
        engine.submit(job(&b, &cfg, 4));
        let report = engine.run().unwrap();
        let inferences: u64 = report.jobs.iter().map(|j| j.inferences).sum();
        assert_eq!(inferences, 5_000);
        assert_eq!(report.stats.slots, inferences);
        assert_eq!(p.served(), 5_000);
        assert!(report.stats.batches > 0);
        assert!(report.stats.slots <= report.stats.batches * report.stats.target_batch as u64);
        assert!(report.stats.mean_occupancy() > 0.0);
        assert_eq!(report.stats.target_batch, 8);
        assert_eq!(report.stats.subtraces, 9);
    }

    #[test]
    fn target_batch_size_does_not_change_results() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("namd", 4_000);
        let mut outs = Vec::new();
        for target in [0usize, 3, 16] {
            let mut p = TablePredictor::new(16);
            let mut engine = BatchEngine::new(&mut p, target);
            engine.submit(job(&recs, &cfg, 6));
            outs.push(engine.run().unwrap().jobs.remove(0));
        }
        assert_eq!(outs[0].cycles, outs[1].cycles);
        assert_eq!(outs[0].cycles, outs[2].cycles);
        assert_eq!(outs[0].windows, outs[1].windows);
        assert_eq!(outs[0].windows, outs[2].windows);
    }

    #[test]
    fn empty_job_completes_immediately() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("xz", 100);
        let mut p = TablePredictor::new(8);
        let mut engine = BatchEngine::new(&mut p, 0);
        engine.submit(job(&[], &cfg, 4));
        engine.submit(job(&recs, &cfg, 2));
        assert_eq!(engine.job_count(), 2);
        let report = engine.run().unwrap();
        assert_eq!(report.jobs[0].instructions, 0);
        assert_eq!(report.jobs[0].cycles, 0);
        assert_eq!(report.jobs[1].instructions, 100);
        assert_eq!(report.stats.subtraces, 2);
    }

    #[test]
    fn merged_report_concatenates_jobs() {
        let cfg = SimConfig::default_o3();
        let a = make_records("gcc", 2_000);
        let b = make_records("mcf", 1_000);
        let mut p = TablePredictor::new(16);
        let mut engine = BatchEngine::new(&mut p, 0);
        engine.submit(job(&a, &cfg, 2));
        engine.submit(job(&b, &cfg, 1));
        let merged = engine.run().unwrap().merged();
        assert_eq!(merged.instructions, 3_000);
        assert_eq!(merged.inferences, 3_000);
        let w: u64 = merged.windows.iter().map(|(n, _)| n).sum();
        assert_eq!(w, 3_000);
    }
}
