//! Shared dynamic-batching simulation engine (paper §3.3, Figures 4/9),
//! pipelined across encode workers.
//!
//! SimNet's throughput comes entirely from turning the inherently
//! sequential prediction chain into accelerator-sized batches: §3.3
//! splits one trace into sub-traces and batches their per-step
//! predictions (Figure 4), and Figure 9 scales that across devices by
//! sharding sub-traces over workers. The seed implementation capped the
//! batch at one worker's private sub-trace count — each pool worker
//! owned its own predictor, so batches never crossed worker or job
//! boundaries and predictor occupancy collapsed as workers grew.
//!
//! [`BatchEngine`] inverts that: a job-queue front end accepts many
//! concurrent simulation jobs ([`JobSpec`]: record view + `SimConfig` +
//! config feature), and the scheduler multiplexes the next-instruction
//! slots of *all* active sub-traces across *all* jobs into shared
//! [`LatencyPredictor`] batches with a configurable target batch size.
//! This is the software analogue of the paper's multi-GPU claim ("no
//! inter-device communication is required"): sub-traces only meet inside
//! a predictor batch, so scheduling order cannot change any job's
//! result — each prediction depends only on that sub-trace's own context
//! queue. Results are demuxed deterministically back to each job's
//! `ContextTracker`s and CPI windows, and per-batch occupancy /
//! starvation counters ([`EngineStats`]) expose how full the
//! accelerator batches actually ran — the quantity Figures 8/9 sweep.
//!
//! One simulation round advances every active sub-trace by exactly one
//! instruction: slots are gathered in deterministic (job, sub-trace)
//! submission order, chunked to the target batch size, predicted, and
//! scattered back. Total cycles per job remain the sum of its sub-trace
//! `curTick`s plus drain (Eq. 1), exactly as in [`super::parallel`].
//!
//! # Pipelining
//!
//! The paper overlaps CPU-side feature preparation with accelerator
//! inference so the predictor never waits on encoding. With
//! [`EngineOptions::encode_threads`] > 1 the engine runs the same
//! schedule on a pool of encode workers: sub-traces are sharded
//! round-robin over workers (worker `w` owns global sub-trace `g` iff
//! `g % workers == w`), and each worker both *encodes* its slots of
//! every batch and *scatters* the predictions back into its own context
//! trackers — no sub-trace is ever shared between threads. The caller
//! thread only runs the predictor and orchestrates. With
//! [`EngineOptions::pipeline_depth`] ≥ 2 the batch buffers are
//! double-buffered (ring of `depth` buffers), so encoding of batch *k+1*
//! overlaps prediction of batch *k* whenever the two batches touch
//! disjoint sub-traces; a round-boundary frontier gate withholds encode
//! commands that would race a pending scatter, which keeps the pipelined
//! schedule *byte-identical* to the serial one (same batches, same
//! predictions, same cycle counts, same occupancy statistics).
//!
//! # Forked per-worker prediction
//!
//! Because each prediction depends only on its sub-trace's own context
//! queue, the predictor itself can be replicated, not just the encode
//! work: when the predictor supports [`LatencyPredictor::fork`] (the
//! native backend forks `clone_lite` handles over one shared weight
//! arena; the table predictor copies its constants) and
//! [`EngineOptions::fork_predict`] is on (the default), every encode
//! worker owns a forked handle and runs encode → predict → scatter for
//! its own sub-traces with no cross-thread communication at all — no
//! command channels, no shared batch buffers, no serialization on one
//! predictor's scratch state. Workers walk the same deterministic chunk
//! schedule as the serial loop, so per-batch statistics and every
//! simulation result stay byte-identical; only wall-clock behavior
//! changes ([`EngineStats::predict_seconds`] then reports the slowest
//! worker's predict time — the critical path). Predictors that cannot
//! fork (e.g. a single PJRT device handle) fall back to the shared-handle
//! pipelined loop above.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::des::SimConfig;
use crate::features::soa::SoaBatch;
use crate::features::{ContextTracker, NUM_FEATURES};
use crate::predictor::LatencyPredictor;
use crate::trace::{RecordCursor, RecordsView};

use super::SimOutcome;

/// One simulation job submitted to the engine.
pub struct JobSpec<'a> {
    /// Records to simulate: a decoded slice (`(&recs[..]).into()`) or a
    /// streaming view over a mapped trace ([`crate::trace::RecordStore`]).
    pub records: RecordsView<'a>,
    /// Machine configuration for the job's context trackers.
    pub cfg: &'a SimConfig,
    /// Sub-trace parallelism within the job (clamped to the trace size).
    pub subtraces: usize,
    /// CPI window in instructions (0 = no windows).
    pub window: u64,
    /// Configuration input feature (§5 ROB study), 0.0 when unused.
    pub cfg_feature: f32,
    /// Live progress counter, bumped once per simulated instruction
    /// across all of the job's sub-traces (relaxed ordering — readers
    /// only need an eventually-fresh count, not synchronization). The
    /// job server hands one in per job to stream progress events;
    /// `None` costs nothing on the hot path.
    pub progress: Option<Arc<AtomicU64>>,
}

/// Execution knobs for [`BatchEngine`] (CLI: `--target-batch`,
/// `--encode-threads`, `--pipeline-depth`, `--no-fork-predict`).
///
/// # Examples
///
/// ```
/// use simnet::coordinator::EngineOptions;
///
/// let opts = EngineOptions { encode_threads: 4, ..EngineOptions::default() };
/// assert_eq!(opts.target_batch, 0); // one batch per round
/// assert_eq!(opts.pipeline_depth, 2); // double-buffered
/// assert!(opts.fork_predict); // per-worker handles when the predictor forks
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineOptions {
    /// Target predictor-batch size (0 = all active sub-traces per batch).
    pub target_batch: usize,
    /// Encode/scatter worker threads (≤1 = serial in the caller thread).
    pub encode_threads: usize,
    /// Batch buffers in flight: 1 runs encode → predict in lockstep, ≥2
    /// overlaps encoding of batch k+1 with prediction of batch k.
    pub pipeline_depth: usize,
    /// Give each encode worker its own forked predictor handle
    /// ([`LatencyPredictor::fork`]) so workers encode, predict, and
    /// scatter independently. Falls back to the shared-handle pipelined
    /// loop when the predictor cannot fork; results are byte-identical
    /// either way. Only takes effect with `encode_threads` > 1.
    pub fork_predict: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        // Depth 2 = double-buffering, the documented default; it only
        // takes effect once encode_threads > 1 (serial runs force 1).
        EngineOptions { target_batch: 0, encode_threads: 1, pipeline_depth: 2, fork_predict: true }
    }
}

/// Per-run predictor-batch statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Predictor calls issued.
    pub batches: u64,
    /// Total filled slots across all batches (== total inferences).
    pub slots: u64,
    /// Effective batch-size target (configured target, or the initial
    /// active sub-trace count when running unbounded).
    pub target_batch: usize,
    /// Batches that went out with fewer slots than the target.
    pub starved: u64,
    /// Batches that went out exactly at the target size
    /// (`batches - starved`; schedule-derived, so identical across the
    /// serial, pipelined, and forked loops).
    pub filled: u64,
    /// Sub-traces created across all jobs.
    pub subtraces: u64,
    /// Encode/scatter worker threads the run used (1 = serial loop).
    pub encode_threads: usize,
    /// Batch buffers in flight (1 = no encode/predict overlap).
    pub pipeline_depth: usize,
    /// Wall seconds spent filling and interleaving the SoA encode panels.
    /// Serial runs report the caller thread's total; threaded runs report
    /// the slowest worker's encode time (the critical path), mirroring
    /// `predict_seconds`.
    pub encode_seconds: f64,
    /// Wall seconds spent inside `LatencyPredictor::predict` calls. With
    /// forked per-worker handles this is the slowest worker's predict
    /// time — the critical path — so derived throughput stays meaningful.
    pub predict_seconds: f64,
    /// Wall seconds of the engine run itself (excludes predictor
    /// construction / artifact load, unlike a pool's reported wall time).
    pub engine_seconds: f64,
}

impl EngineStats {
    /// Mean filled slots per predictor call.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.slots as f64 / self.batches as f64
        }
    }

    /// Mean batch fill as a fraction of the target batch size.
    pub fn fill_ratio(&self) -> f64 {
        if self.target_batch == 0 {
            0.0
        } else {
            self.mean_occupancy() / self.target_batch as f64
        }
    }

    /// Fraction of the engine's own wall time the predictor spent *not*
    /// predicting (waiting on encode, scatter, and orchestration) — the
    /// quantity the pipeline exists to minimize. Measured against
    /// `engine_seconds`, so predictor construction does not count as idle.
    pub fn predictor_idle(&self) -> f64 {
        if self.engine_seconds <= 0.0 {
            0.0
        } else {
            (1.0 - self.predict_seconds / self.engine_seconds).clamp(0.0, 1.0)
        }
    }
}

/// Outcome of an engine run: one [`SimOutcome`] per job (submission
/// order) plus shared batching statistics.
#[derive(Debug, Clone, Default)]
pub struct EngineReport {
    pub jobs: Vec<SimOutcome>,
    pub stats: EngineStats,
    pub wall_seconds: f64,
}

impl EngineReport {
    /// Merge all per-job outcomes into one (window lists concatenate in
    /// job submission order; wall time is the shared engine wall time).
    pub fn merged(self) -> SimOutcome {
        let wall = self.wall_seconds;
        let mut merged = SimOutcome::default();
        for job in self.jobs {
            merged.instructions += job.instructions;
            merged.cycles += job.cycles;
            merged.inferences += job.inferences;
            merged.windows.extend(job.windows);
        }
        merged.wall_seconds = wall;
        merged
    }

    /// [`EngineStats::predictor_idle`] of this report's engine run.
    pub fn predictor_idle_fraction(&self) -> f64 {
        self.stats.predictor_idle()
    }
}

struct SubTrace<'a> {
    /// Windowed reader over this sub-trace's records (zero-cost over
    /// decoded slices; a bounded decode buffer over mapped traces).
    cur: RecordCursor<'a>,
    /// Records in the sub-trace (cached; `cur.len()` behind one match).
    len: usize,
    pos: usize,
    tracker: ContextTracker,
    windows: Vec<(u64, u64)>,
    window_insts: u64,
    window_start: u64,
    /// CPI window length in instructions (0 = none), from the job spec.
    window: u64,
    /// Owning job index (for outcome reassembly).
    job: usize,
    /// The owning job's shared progress counter, if it has one.
    progress: Option<Arc<AtomicU64>>,
}

/// Multi-job shared-batch simulation engine. Construct with a predictor
/// and a target batch size (0 = one batch per round over every active
/// sub-trace) — or [`with_options`](Self::with_options) for the pipelined
/// multi-threaded configuration — then [`submit`](Self::submit) any
/// number of jobs and [`run`](Self::run).
pub struct BatchEngine<'a, 'p> {
    predictor: &'p mut dyn LatencyPredictor,
    opts: EngineOptions,
    seq: usize,
    width: usize,
    subs: Vec<SubTrace<'a>>,
    n_jobs: usize,
}

impl<'a, 'p> BatchEngine<'a, 'p> {
    pub fn new(predictor: &'p mut dyn LatencyPredictor, target_batch: usize) -> Self {
        Self::with_options(predictor, EngineOptions { target_batch, ..EngineOptions::default() })
    }

    /// Construct with full execution options (threads + pipeline depth).
    pub fn with_options(predictor: &'p mut dyn LatencyPredictor, opts: EngineOptions) -> Self {
        let seq = predictor.seq_len();
        BatchEngine { predictor, opts, seq, width: seq * NUM_FEATURES, subs: Vec::new(), n_jobs: 0 }
    }

    /// Queue a job; returns its index into [`EngineReport::jobs`].
    pub fn submit(&mut self, spec: JobSpec<'a>) -> usize {
        let job = self.n_jobs;
        self.n_jobs += 1;
        let n = spec.records.len();
        if n > 0 {
            let mode = self.predictor.context_mode();
            let s = spec.subtraces.clamp(1, n);
            let chunk = n.div_ceil(s);
            let mut lo = 0;
            while lo < n {
                let hi = (lo + chunk).min(n);
                let sub = spec.records.slice(lo, hi);
                lo = hi;
                let mut tracker = ContextTracker::with_mode(spec.cfg, mode);
                tracker.cfg_feature = spec.cfg_feature;
                self.subs.push(SubTrace {
                    len: sub.len(),
                    cur: sub.cursor(),
                    pos: 0,
                    tracker,
                    windows: Vec::new(),
                    window_insts: 0,
                    window_start: 0,
                    window: spec.window,
                    job,
                    progress: spec.progress.clone(),
                });
            }
        }
        job
    }

    /// Number of jobs queued so far.
    pub fn job_count(&self) -> usize {
        self.n_jobs
    }

    /// Drive every queued job to completion, multiplexing all active
    /// sub-traces into shared predictor batches.
    pub fn run(self) -> Result<EngineReport> {
        let BatchEngine { predictor, opts, seq, width, mut subs, n_jobs } = self;
        let total = subs.len();
        // Clamp to the active sub-trace count: a batch can never hold
        // more slots than sub-traces, and the gather buffers are sized by
        // this (an unclamped huge --target-batch must not OOM).
        let cap = if opts.target_batch == 0 {
            total.max(1)
        } else {
            opts.target_batch.min(total).max(1)
        };
        let threads = opts.encode_threads.max(1).min(total.max(1));
        let depth = if threads <= 1 { 1 } else { opts.pipeline_depth.max(1) };
        let mut stats = EngineStats {
            target_batch: cap,
            subtraces: total as u64,
            encode_threads: threads,
            pipeline_depth: depth,
            ..EngineStats::default()
        };
        let t0 = Instant::now();
        if threads <= 1 {
            serial_loop(predictor, &mut subs, cap, seq, width, &mut stats)?;
        } else {
            let pcfg = PipelineCfg { cap, threads, depth, seq, width };
            let handles = if opts.fork_predict { fork_handles(&*predictor, threads) } else { None };
            subs = match handles {
                Some(h) => forked_loop(predictor, h, subs, &pcfg, &mut stats)?,
                None => pipelined_loop(predictor, subs, &pcfg, &mut stats)?,
            };
        }
        let wall = t0.elapsed().as_secs_f64();
        stats.engine_seconds = wall;

        // Per paper §3.3: each job's total time is the sum of its
        // sub-trace curTicks (post-drain); windows concatenate in
        // original trace order, which is submission order here.
        let mut jobs = vec![SimOutcome::default(); n_jobs];
        for sub in &mut subs {
            let out = &mut jobs[sub.job];
            out.instructions += sub.pos as u64;
            out.cycles += sub.tracker.cur_tick;
            out.windows.extend(sub.windows.drain(..));
        }
        for out in &mut jobs {
            out.inferences = out.instructions;
            out.wall_seconds = wall;
        }
        Ok(EngineReport { jobs, stats, wall_seconds: wall })
    }
}

/// Apply one prediction to its sub-trace: push into the context tracker,
/// advance the cursor, and roll the CPI window. Identical on the serial
/// and pipelined paths — this is the only place latencies enter a job.
fn scatter_one(sub: &mut SubTrace<'_>, pred: (u32, u32, u32)) {
    // Same position the encode just read, so this hits the cursor's
    // window — no second decode on the mapped path.
    let rec = sub.cur.get(sub.pos);
    let (f, e, s_lat) = pred;
    let s_lat = if rec.inst.is_store() { s_lat.max(e + 1) } else { 0 };
    sub.tracker.push(&rec.inst, &rec.hist, f, e.max(1), s_lat);
    sub.pos += 1;
    if let Some(p) = &sub.progress {
        p.fetch_add(1, Ordering::Relaxed);
    }
    sub.window_insts += 1;
    if sub.window > 0 && sub.window_insts == sub.window {
        let cyc = sub.tracker.cur_tick - sub.window_start;
        sub.windows.push((sub.window_insts, cyc));
        sub.window_start = sub.tracker.cur_tick;
        sub.window_insts = 0;
    }
}

/// Flush the trailing partial CPI window and drain the machine.
fn finish_sub(sub: &mut SubTrace<'_>) {
    if sub.window > 0 && sub.window_insts > 0 {
        sub.windows.push((sub.window_insts, sub.tracker.cur_tick - sub.window_start));
    }
    sub.tracker.drain();
}

/// The single-threaded engine loop: gather → predict → scatter, one
/// chunk of at most `cap` slots at a time. The gather stage fills the
/// reusable SoA panels ([`SoaBatch`]) and interleaves them into the AoS
/// predictor batch — bit-identical to encoding each slot directly.
fn serial_loop(
    predictor: &mut dyn LatencyPredictor,
    subs: &mut [SubTrace<'_>],
    cap: usize,
    seq: usize,
    width: usize,
    stats: &mut EngineStats,
) -> Result<()> {
    let mut active: Vec<usize> = (0..subs.len()).filter(|&i| subs[i].len > 0).collect();
    let mut batch = vec![0.0f32; cap * width];
    let mut soa = SoaBatch::new(cap, seq);
    while !active.is_empty() {
        // One round advances every active sub-trace by one instruction,
        // in chunks of at most `cap` slots.
        let mut base = 0;
        while base < active.len() {
            let take = cap.min(active.len() - base);
            // Gather: encode the next instruction of each slot.
            let te = Instant::now();
            for k in 0..take {
                let sub = &mut subs[active[base + k]];
                let rec = sub.cur.get(sub.pos);
                soa.encode_into(
                    &sub.tracker,
                    &rec.inst,
                    &rec.hist,
                    k,
                    &mut batch[k * width..(k + 1) * width],
                );
            }
            stats.encode_seconds += te.elapsed().as_secs_f64();
            // One shared inference across jobs and sub-traces.
            let t = Instant::now();
            let preds = predictor.predict(&batch[..take * width], take)?;
            stats.predict_seconds += t.elapsed().as_secs_f64();
            stats.batches += 1;
            stats.slots += take as u64;
            if take < cap {
                stats.starved += 1;
            } else {
                stats.filled += 1;
            }
            // Scatter: demux predictions back to each slot's sub-trace.
            for k in 0..take {
                scatter_one(&mut subs[active[base + k]], preds[k]);
            }
            base += take;
        }
        active.retain(|&i| subs[i].pos < subs[i].len);
    }
    for sub in subs.iter_mut() {
        finish_sub(sub);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Pipelined multi-threaded loop
// ---------------------------------------------------------------------

/// Effective pipeline configuration (post-clamping).
struct PipelineCfg {
    cap: usize,
    threads: usize,
    depth: usize,
    seq: usize,
    width: usize,
}

/// One predictor batch in the precomputed schedule: `take` slots starting
/// at rank `base` of round `round`'s active list.
#[derive(Clone, Copy)]
struct ChunkDesc {
    round: usize,
    base: usize,
    take: usize,
    round_last: bool,
}

/// Commands the coordinator sends to every encode worker (FIFO per
/// worker; workers act only on the slots whose sub-traces they own).
enum Cmd {
    /// Encode chunk `q` into buffer `q % depth`.
    Encode { q: usize },
    /// Apply chunk `q`'s predictions to the owned sub-traces.
    Scatter { q: usize, preds: Arc<Vec<(u32, u32, u32)>> },
    /// Flush windows, drain trackers, and return the sub-traces.
    Finish,
}

/// Raw pointer to a batch buffer, shared with the encode workers.
///
/// SAFETY: slot ownership partitions every batch (worker `w` writes only
/// slots of sub-traces with `g % workers == w`), the coordinator reads a
/// buffer only after all workers acknowledged encoding its chunk, and a
/// buffer is reused for chunk `q` only after chunk `q - depth` was
/// predicted. The backing allocations outlive the thread scope.
#[derive(Clone, Copy)]
struct BufPtr(*mut f32);

unsafe impl Send for BufPtr {}
unsafe impl Sync for BufPtr {}

/// One run of consecutive rounds whose active count (and therefore chunk
/// structure) is constant.
struct Segment {
    /// Index of the segment's first chunk in the global schedule.
    first_chunk: usize,
    first_round: usize,
    /// Active sub-traces throughout the segment.
    active: usize,
    chunks_per_round: usize,
}

/// The deterministic batch schedule in O(#sub-traces) memory. Every
/// sub-trace advances exactly one instruction per round, so round `r`'s
/// active list is "every sub-trace with more than `r` records, in
/// submission order" and the chunking mirrors [`serial_loop`] exactly.
/// The active count only drops at the (sorted) distinct sub-trace
/// lengths, so the schedule is a handful of constant-shape [`Segment`]s
/// and per-chunk descriptors are computed on demand — nothing is
/// materialized per round or per batch.
struct Schedule {
    cap: usize,
    segments: Vec<Segment>,
    total_chunks: usize,
}

impl Schedule {
    fn plan(lens: &[usize], cap: usize) -> Schedule {
        let mut sorted: Vec<usize> = lens.iter().copied().filter(|&l| l > 0).collect();
        sorted.sort_unstable();
        let n = sorted.len();
        let mut segments = Vec::new();
        let mut first_chunk = 0usize;
        let mut round = 0usize;
        let mut i = 0usize;
        while i < n {
            // lens[i..] are all still active; the segment runs until the
            // smallest live length expires.
            let active = n - i;
            let seg_end = sorted[i];
            let chunks_per_round = active.div_ceil(cap);
            segments.push(Segment { first_chunk, first_round: round, active, chunks_per_round });
            first_chunk += (seg_end - round) * chunks_per_round;
            round = seg_end;
            while i < n && sorted[i] == seg_end {
                i += 1;
            }
        }
        Schedule { cap, segments, total_chunks: first_chunk }
    }

    /// Descriptor of chunk `q` (requires `q < total_chunks`).
    fn desc(&self, q: usize) -> ChunkDesc {
        let si = self.segments.partition_point(|s| s.first_chunk <= q) - 1;
        let s = &self.segments[si];
        let idx = q - s.first_chunk;
        let round = s.first_round + idx / s.chunks_per_round;
        let k = idx % s.chunks_per_round;
        let base = k * self.cap;
        ChunkDesc {
            round,
            base,
            take: self.cap.min(s.active - base),
            round_last: k + 1 == s.chunks_per_round,
        }
    }
}

/// Sends a sentinel ack if the worker unwinds, so the coordinator turns a
/// worker panic into an error (and the scope re-raises the panic at join)
/// instead of waiting forever for an ack that will never come.
struct PanicSentinel {
    tx: mpsc::Sender<usize>,
    armed: bool,
}

impl Drop for PanicSentinel {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.tx.send(usize::MAX);
        }
    }
}

/// Per-worker state moved into an encode thread.
struct WorkerCtx<'a> {
    /// This worker's index (owns sub-trace `g` iff `g % workers == w`).
    w: usize,
    workers: usize,
    /// Owned sub-traces, in increasing global-index order (local = g / workers).
    subs: Vec<SubTrace<'a>>,
    rx: mpsc::Receiver<Cmd>,
    done_tx: mpsc::Sender<usize>,
    sched: Arc<Schedule>,
    /// Record count of EVERY sub-trace (global order) — each worker
    /// replays the global active list from these to find its slots.
    lens: Arc<Vec<usize>>,
    bufs: Vec<BufPtr>,
    depth: usize,
    cap: usize,
    seq: usize,
    width: usize,
}

fn encode_worker<'a>(mut cx: WorkerCtx<'a>) -> (usize, Vec<SubTrace<'a>>, f64) {
    let mut sentinel = PanicSentinel { tx: cx.done_tx.clone(), armed: true };
    let mut cur_round = 0usize;
    let mut active: Vec<usize> = (0..cx.lens.len()).filter(|&g| cx.lens[g] > 0).collect();
    // Private SoA panels, reused for every chunk this worker encodes.
    let mut soa = SoaBatch::new(cx.cap, cx.seq);
    let mut encode_seconds = 0.0f64;
    while let Ok(cmd) = cx.rx.recv() {
        match cmd {
            Cmd::Encode { q } => {
                let d = cx.sched.desc(q);
                // Advance the replicated active list to the chunk's round
                // (command order guarantees rounds arrive non-decreasing,
                // and never before the previous round's scatter).
                while cur_round < d.round {
                    cur_round += 1;
                    let r = cur_round;
                    let lens = &cx.lens;
                    active.retain(|&g| lens[g] > r);
                }
                let buf = cx.bufs[q % cx.depth];
                let te = Instant::now();
                for s in d.base..d.base + d.take {
                    let g = active[s];
                    if g % cx.workers == cx.w {
                        let width = cx.width;
                        let sub = &mut cx.subs[g / cx.workers];
                        let rec = sub.cur.get(sub.pos);
                        // SAFETY: see [`BufPtr`] — this worker exclusively
                        // owns slot `s` of this chunk, and the protocol
                        // serializes buffer reuse and the coordinator read.
                        let out = unsafe {
                            std::slice::from_raw_parts_mut(buf.0.add((s - d.base) * width), width)
                        };
                        soa.encode_into(&sub.tracker, &rec.inst, &rec.hist, s - d.base, out);
                    }
                }
                encode_seconds += te.elapsed().as_secs_f64();
                // Coordinator may be gone on an error path; just exit then.
                if cx.done_tx.send(q).is_err() {
                    break;
                }
            }
            Cmd::Scatter { q, preds } => {
                let d = cx.sched.desc(q);
                for s in d.base..d.base + d.take {
                    let g = active[s];
                    if g % cx.workers == cx.w {
                        scatter_one(&mut cx.subs[g / cx.workers], preds[s - d.base]);
                    }
                }
            }
            Cmd::Finish => {
                for sub in cx.subs.iter_mut() {
                    finish_sub(sub);
                }
                break;
            }
        }
    }
    // A recv error means the coordinator bailed early; return the
    // sub-traces as-is — the caller is about to discard them.
    sentinel.armed = false;
    (cx.w, cx.subs, encode_seconds)
}

/// The pipelined engine loop. Runs the exact schedule of [`serial_loop`]
/// on `threads` encode/scatter workers with a ring of `depth` batch
/// buffers; the caller thread runs the predictor. Returns the sub-traces
/// in their original submission order.
fn pipelined_loop<'a>(
    predictor: &mut dyn LatencyPredictor,
    subs: Vec<SubTrace<'a>>,
    pcfg: &PipelineCfg,
    stats: &mut EngineStats,
) -> Result<Vec<SubTrace<'a>>> {
    let (cap, workers) = (pcfg.cap, pcfg.threads);
    let (seq, width) = (pcfg.seq, pcfg.width);
    let total = subs.len();
    let lens: Arc<Vec<usize>> = Arc::new(subs.iter().map(|s| s.len).collect());
    let sched = Arc::new(Schedule::plan(&lens, cap));
    let n_chunks = sched.total_chunks;
    if n_chunks == 0 {
        return Ok(subs);
    }
    // Buffers beyond the chunk count can never be in flight; clamping
    // keeps the ring allocation bounded against a huge --pipeline-depth
    // (mirrors the target-batch clamp in `run`).
    let depth = pcfg.depth.min(n_chunks).max(1);
    stats.pipeline_depth = depth;

    // Shard sub-trace ownership round-robin over the workers. Each worker
    // does all encoding AND scattering for its own sub-traces, so no
    // tracker is ever touched by two threads.
    let mut worker_subs: Vec<Vec<SubTrace<'a>>> = (0..workers).map(|_| Vec::new()).collect();
    for (g, sub) in subs.into_iter().enumerate() {
        worker_subs[g % workers].push(sub);
    }

    let mut buf_store: Vec<Vec<f32>> = (0..depth).map(|_| vec![0.0f32; cap * width]).collect();
    let buf_ptrs: Vec<BufPtr> = buf_store.iter_mut().map(|b| BufPtr(b.as_mut_ptr())).collect();

    let collected = thread::scope(|scope| -> Result<Vec<(usize, Vec<SubTrace<'a>>, f64)>> {
        let (done_tx, done_rx) = mpsc::channel::<usize>();
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for (w, mine) in worker_subs.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Cmd>();
            cmd_txs.push(tx);
            let cx = WorkerCtx {
                w,
                workers,
                subs: mine,
                rx,
                done_tx: done_tx.clone(),
                sched: Arc::clone(&sched),
                lens: Arc::clone(&lens),
                bufs: buf_ptrs.clone(),
                depth,
                cap,
                seq,
                width,
            };
            handles.push(scope.spawn(move || encode_worker(cx)));
        }
        // Workers hold the only done senders: a dying worker surfaces as a
        // recv error instead of a hang.
        drop(done_tx);

        // Ack counters for the in-flight chunk window [p, p + depth - 1]
        // (distinct mod depth); each slot is reset as its wait completes.
        let mut done = vec![0u32; depth];
        let mut issued = 0usize;
        // Rounds `< frontier + 1` have had every scatter command sent, so
        // encode commands for rounds `<= frontier` cannot race a pending
        // scatter on any worker (per-worker FIFO does the rest). This gate
        // is what keeps the pipeline byte-identical to the serial loop.
        let mut frontier = 0usize;
        for p in 0..n_chunks {
            // Issue encodes ahead, up to the buffer ring and the frontier.
            while issued < n_chunks
                && issued <= p + depth - 1
                && sched.desc(issued).round <= frontier
            {
                for tx in &cmd_txs {
                    tx.send(Cmd::Encode { q: issued })
                        .map_err(|_| anyhow!("encode worker exited early"))?;
                }
                issued += 1;
            }
            // Predictor-idle time: waiting for the encode acks.
            while done[p % depth] < workers as u32 {
                let q = done_rx.recv().map_err(|_| anyhow!("encode worker exited early"))?;
                if q == usize::MAX {
                    // A worker's panic sentinel: bail out; the scope's join
                    // re-raises the panic itself.
                    return Err(anyhow!("encode worker panicked"));
                }
                done[q % depth] += 1;
            }
            done[p % depth] = 0;
            let d = sched.desc(p);
            // SAFETY: see [`BufPtr`] — every worker acknowledged chunk p,
            // and no unpredicted chunk maps to this buffer.
            let input = unsafe {
                std::slice::from_raw_parts(buf_ptrs[p % depth].0.cast_const(), d.take * width)
            };
            let t = Instant::now();
            let preds = predictor.predict(input, d.take)?;
            stats.predict_seconds += t.elapsed().as_secs_f64();
            stats.batches += 1;
            stats.slots += d.take as u64;
            if d.take < cap {
                stats.starved += 1;
            } else {
                stats.filled += 1;
            }
            let preds = Arc::new(preds);
            for tx in &cmd_txs {
                tx.send(Cmd::Scatter { q: p, preds: Arc::clone(&preds) })
                    .map_err(|_| anyhow!("encode worker exited early"))?;
            }
            if d.round_last {
                frontier = d.round + 1;
            }
        }
        for tx in &cmd_txs {
            tx.send(Cmd::Finish).map_err(|_| anyhow!("encode worker exited early"))?;
        }
        let mut collected = Vec::with_capacity(workers);
        for h in handles {
            collected.push(h.join().expect("encode worker panicked"));
        }
        Ok(collected)
    })?;
    drop(buf_ptrs);
    drop(buf_store);

    // Reassemble global submission order (g = local * workers + w) and
    // charge the slowest worker's encode time (the critical path).
    let mut out: Vec<Option<SubTrace<'a>>> = (0..total).map(|_| None).collect();
    let mut encode_crit = 0.0f64;
    for (w, mine, encode_secs) in collected {
        encode_crit = encode_crit.max(encode_secs);
        for (local, sub) in mine.into_iter().enumerate() {
            out[local * workers + w] = Some(sub);
        }
    }
    stats.encode_seconds += encode_crit;
    Ok(out.into_iter().map(|s| s.expect("sub-trace lost in pipeline")).collect())
}

// ---------------------------------------------------------------------
// Forked per-worker prediction loop
// ---------------------------------------------------------------------

/// Fork `n` per-worker predictor handles, all-or-nothing. `None` when the
/// predictor does not support forking — the engine then falls back to the
/// shared-handle pipelined loop.
fn fork_handles(
    predictor: &dyn LatencyPredictor,
    n: usize,
) -> Option<Vec<Box<dyn LatencyPredictor>>> {
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        handles.push(predictor.fork()?);
    }
    Some(handles)
}

/// Everything one forked worker owns: its sub-trace shard, its private
/// predictor handle, and the shared read-only schedule.
struct ForkedCtx<'a> {
    /// This worker's index (owns sub-trace `g` iff `g % workers == w`).
    w: usize,
    workers: usize,
    /// Owned sub-traces, in increasing global-index order (local = g / workers).
    subs: Vec<SubTrace<'a>>,
    predictor: Box<dyn LatencyPredictor>,
    sched: Arc<Schedule>,
    /// Record count of EVERY sub-trace (global order) — each worker
    /// replays the global active list from these to find its slots.
    lens: Arc<Vec<usize>>,
    cap: usize,
    seq: usize,
    width: usize,
}

/// One forked worker: walks the global chunk schedule and, per chunk,
/// encodes its owned slots into a private batch, predicts them on its own
/// handle, and scatters — fully independent of every other worker.
/// Returns the shard, the handle's served count, and its predict and
/// encode wall times.
fn forked_worker<'a>(mut cx: ForkedCtx<'a>) -> Result<(usize, Vec<SubTrace<'a>>, u64, f64, f64)> {
    let mut cur_round = 0usize;
    let mut active: Vec<usize> = (0..cx.lens.len()).filter(|&g| cx.lens[g] > 0).collect();
    let mut batch = vec![0.0f32; cx.cap * cx.width];
    let mut soa = SoaBatch::new(cx.cap, cx.seq);
    let mut owned: Vec<usize> = Vec::with_capacity(cx.cap);
    let mut predict_seconds = 0.0f64;
    let mut encode_seconds = 0.0f64;
    for q in 0..cx.sched.total_chunks {
        let d = cx.sched.desc(q);
        // Advance the replicated active list to the chunk's round (chunks
        // arrive in non-decreasing round order by construction).
        while cur_round < d.round {
            cur_round += 1;
            let r = cur_round;
            let lens = &cx.lens;
            active.retain(|&g| lens[g] > r);
        }
        owned.clear();
        for s in d.base..d.base + d.take {
            let g = active[s];
            if g % cx.workers == cx.w {
                owned.push(g / cx.workers);
            }
        }
        if owned.is_empty() {
            continue;
        }
        // Gather the owned slots contiguously; the chunk cap bounds the
        // private batch exactly as it bounds the serial loop's.
        let te = Instant::now();
        for (k, &local) in owned.iter().enumerate() {
            let sub = &mut cx.subs[local];
            let rec = sub.cur.get(sub.pos);
            soa.encode_into(
                &sub.tracker,
                &rec.inst,
                &rec.hist,
                k,
                &mut batch[k * cx.width..(k + 1) * cx.width],
            );
        }
        encode_seconds += te.elapsed().as_secs_f64();
        let t = Instant::now();
        let preds = cx.predictor.predict(&batch[..owned.len() * cx.width], owned.len())?;
        predict_seconds += t.elapsed().as_secs_f64();
        for (k, &local) in owned.iter().enumerate() {
            scatter_one(&mut cx.subs[local], preds[k]);
        }
    }
    for sub in cx.subs.iter_mut() {
        finish_sub(sub);
    }
    Ok((cx.w, cx.subs, cx.predictor.served(), predict_seconds, encode_seconds))
}

/// The forked engine loop: shard sub-traces over `threads` workers, each
/// with its own predictor handle, and let every worker run the whole
/// encode → predict → scatter cycle for its shard. Batch composition
/// cannot change any result (each prediction depends only on its own
/// sub-trace), and the reported statistics are recomputed from the same
/// deterministic [`Schedule`] the serial loop executes, so reports stay
/// byte-identical to the serial and pipelined paths.
fn forked_loop<'a>(
    predictor: &mut dyn LatencyPredictor,
    handles: Vec<Box<dyn LatencyPredictor>>,
    subs: Vec<SubTrace<'a>>,
    pcfg: &PipelineCfg,
    stats: &mut EngineStats,
) -> Result<Vec<SubTrace<'a>>> {
    let (cap, workers) = (pcfg.cap, pcfg.threads);
    let total = subs.len();
    let lens: Arc<Vec<usize>> = Arc::new(subs.iter().map(|s| s.len).collect());
    let sched = Arc::new(Schedule::plan(&lens, cap));
    let n_chunks = sched.total_chunks;
    if n_chunks == 0 {
        return Ok(subs);
    }
    // Report the same effective depth the pipelined loop would: forked
    // workers inherently overlap encode and predict, the ring just never
    // materializes.
    stats.pipeline_depth = pcfg.depth.min(n_chunks).max(1);
    // Occupancy statistics are a property of the deterministic schedule,
    // not of which handle predicted which rows.
    for q in 0..n_chunks {
        let d = sched.desc(q);
        stats.batches += 1;
        stats.slots += d.take as u64;
        if d.take < cap {
            stats.starved += 1;
        } else {
            stats.filled += 1;
        }
    }

    let mut worker_subs: Vec<Vec<SubTrace<'a>>> = (0..workers).map(|_| Vec::new()).collect();
    for (g, sub) in subs.into_iter().enumerate() {
        worker_subs[g % workers].push(sub);
    }

    let joined = thread::scope(|scope| {
        let mut spawned = Vec::with_capacity(workers);
        for ((w, mine), handle) in worker_subs.into_iter().enumerate().zip(handles) {
            let cx = ForkedCtx {
                w,
                workers,
                subs: mine,
                predictor: handle,
                sched: Arc::clone(&sched),
                lens: Arc::clone(&lens),
                cap,
                seq: pcfg.seq,
                width: pcfg.width,
            };
            spawned.push(scope.spawn(move || forked_worker(cx)));
        }
        spawned
            .into_iter()
            .map(|h| h.join().expect("forked worker panicked"))
            .collect::<Vec<_>>()
    });

    // Reassemble global submission order (g = local * workers + w); fold
    // each handle's served count back into the parent and charge the
    // slowest worker's predict and encode times (the critical paths).
    let mut out: Vec<Option<SubTrace<'a>>> = (0..total).map(|_| None).collect();
    let mut crit_path = 0.0f64;
    let mut encode_crit = 0.0f64;
    for res in joined {
        let (w, mine, served, secs, encode_secs) = res?;
        predictor.absorb_served(served);
        crit_path = crit_path.max(secs);
        encode_crit = encode_crit.max(encode_secs);
        for (local, sub) in mine.into_iter().enumerate() {
            out[local * workers + w] = Some(sub);
        }
    }
    stats.predict_seconds += crit_path;
    stats.encode_seconds += encode_crit;
    Ok(out.into_iter().map(|s| s.expect("sub-trace lost in forked run")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{simulate_parallel_with, ParallelOptions};
    use crate::des::simulate;
    use crate::predictor::TablePredictor;
    use crate::trace::TraceRecord;
    use crate::workload::find;

    fn make_records(bench: &str, n: u64) -> Vec<TraceRecord> {
        let cfg = SimConfig::default_o3();
        let b = find(bench).unwrap();
        let mut recs = Vec::new();
        simulate(&cfg, b.workload(0).stream(), n, |e| recs.push(TraceRecord::from(e)));
        recs
    }

    fn job<'a>(records: &'a [TraceRecord], cfg: &'a SimConfig, subtraces: usize) -> JobSpec<'a> {
        JobSpec {
            records: records.into(),
            cfg,
            subtraces,
            window: 1_000,
            cfg_feature: 0.0,
            progress: None,
        }
    }

    #[test]
    fn single_job_engine_equals_simulate_parallel() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("gcc", 6_000);
        let mut p1 = TablePredictor::new(16);
        let opts = ParallelOptions { subtraces: 4, window: 1_000, ..ParallelOptions::default() };
        let par = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p1, &opts).unwrap();
        let mut p2 = TablePredictor::new(16);
        let mut engine = BatchEngine::new(&mut p2, 0);
        engine.submit(job(&recs, &cfg, 4));
        let report = engine.run().unwrap();
        assert_eq!(report.jobs.len(), 1);
        let out = &report.jobs[0];
        assert_eq!(out.instructions, par.instructions);
        assert_eq!(out.cycles, par.cycles);
        assert_eq!(out.windows, par.windows);
        assert_eq!(report.stats.subtraces, 4);
    }

    #[test]
    fn submission_order_does_not_change_per_job_results() {
        let cfg = SimConfig::default_o3();
        let a = make_records("gcc", 5_000);
        let b = make_records("mcf", 4_000);
        let mut p1 = TablePredictor::new(16);
        let mut e1 = BatchEngine::new(&mut p1, 0);
        e1.submit(job(&a, &cfg, 4));
        e1.submit(job(&b, &cfg, 3));
        let r1 = e1.run().unwrap();
        let mut p2 = TablePredictor::new(16);
        let mut e2 = BatchEngine::new(&mut p2, 0);
        e2.submit(job(&b, &cfg, 3));
        e2.submit(job(&a, &cfg, 4));
        let r2 = e2.run().unwrap();
        // Per-job results must be identical regardless of submission order.
        assert_eq!(r1.jobs[0].cycles, r2.jobs[1].cycles);
        assert_eq!(r1.jobs[0].windows, r2.jobs[1].windows);
        assert_eq!(r1.jobs[1].cycles, r2.jobs[0].cycles);
        assert_eq!(r1.jobs[1].windows, r2.jobs[0].windows);
        assert_eq!(r1.stats.subtraces, r2.stats.subtraces);
    }

    #[test]
    fn occupancy_slots_sum_to_total_inferences() {
        let cfg = SimConfig::default_o3();
        let a = make_records("leela", 3_000);
        let b = make_records("xz", 2_000);
        let mut p = TablePredictor::new(16);
        let mut engine = BatchEngine::new(&mut p, 8);
        engine.submit(job(&a, &cfg, 5));
        engine.submit(job(&b, &cfg, 4));
        let report = engine.run().unwrap();
        let inferences: u64 = report.jobs.iter().map(|j| j.inferences).sum();
        assert_eq!(inferences, 5_000);
        assert_eq!(report.stats.slots, inferences);
        assert_eq!(p.served(), 5_000);
        assert!(report.stats.batches > 0);
        assert_eq!(report.stats.filled + report.stats.starved, report.stats.batches);
        assert!(report.stats.slots <= report.stats.batches * report.stats.target_batch as u64);
        assert!(report.stats.mean_occupancy() > 0.0);
        assert_eq!(report.stats.target_batch, 8);
        assert_eq!(report.stats.subtraces, 9);
    }

    #[test]
    fn target_batch_size_does_not_change_results() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("namd", 4_000);
        let mut outs = Vec::new();
        for target in [0usize, 3, 16] {
            let mut p = TablePredictor::new(16);
            let mut engine = BatchEngine::new(&mut p, target);
            engine.submit(job(&recs, &cfg, 6));
            outs.push(engine.run().unwrap().jobs.remove(0));
        }
        assert_eq!(outs[0].cycles, outs[1].cycles);
        assert_eq!(outs[0].cycles, outs[2].cycles);
        assert_eq!(outs[0].windows, outs[1].windows);
        assert_eq!(outs[0].windows, outs[2].windows);
    }

    #[test]
    fn empty_job_completes_immediately() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("xz", 100);
        let mut p = TablePredictor::new(8);
        let mut engine = BatchEngine::new(&mut p, 0);
        engine.submit(job(&[], &cfg, 4));
        engine.submit(job(&recs, &cfg, 2));
        assert_eq!(engine.job_count(), 2);
        let report = engine.run().unwrap();
        assert_eq!(report.jobs[0].instructions, 0);
        assert_eq!(report.jobs[0].cycles, 0);
        assert_eq!(report.jobs[1].instructions, 100);
        assert_eq!(report.stats.subtraces, 2);
    }

    #[test]
    fn merged_report_concatenates_jobs() {
        let cfg = SimConfig::default_o3();
        let a = make_records("gcc", 2_000);
        let b = make_records("mcf", 1_000);
        let mut p = TablePredictor::new(16);
        let mut engine = BatchEngine::new(&mut p, 0);
        engine.submit(job(&a, &cfg, 2));
        engine.submit(job(&b, &cfg, 1));
        let merged = engine.run().unwrap().merged();
        assert_eq!(merged.instructions, 3_000);
        assert_eq!(merged.inferences, 3_000);
        let w: u64 = merged.windows.iter().map(|(n, _)| n).sum();
        assert_eq!(w, 3_000);
    }

    /// Acceptance criterion of the pipeline refactor: with ≥4 encode
    /// threads the engine must be *byte-identical* to the serial loop —
    /// cycles, windows, instruction counts, AND the occupancy stats.
    /// Holds for both threaded modes: forked per-worker predictor handles
    /// (`fork_predict: true`) and the shared-handle pipelined loop.
    #[test]
    fn pipelined_engine_matches_serial_exactly() {
        let cfg = SimConfig::default_o3();
        let a = make_records("gcc", 6_000);
        let b = make_records("leela", 4_000);
        // target 0 = one chunk per round (no cross-chunk overlap possible);
        // target 4 = multiple chunks per round, exercising the
        // double-buffered encode-ahead path and the round-frontier gate.
        for target in [0usize, 4] {
            let mut p1 = TablePredictor::new(16);
            let mut serial = BatchEngine::new(&mut p1, target);
            serial.submit(job(&a, &cfg, 5));
            serial.submit(job(&b, &cfg, 4));
            let r1 = serial.run().unwrap();
            for fork in [true, false] {
                for (threads, depth) in [(4usize, 2usize), (2, 3), (8, 1)] {
                    let mut p2 = TablePredictor::new(16);
                    let opts = EngineOptions {
                        target_batch: target,
                        encode_threads: threads,
                        pipeline_depth: depth,
                        fork_predict: fork,
                    };
                    let mut piped = BatchEngine::with_options(&mut p2, opts);
                    piped.submit(job(&a, &cfg, 5));
                    piped.submit(job(&b, &cfg, 4));
                    let r2 = piped.run().unwrap();
                    assert_eq!(r1.jobs.len(), r2.jobs.len());
                    for (j1, j2) in r1.jobs.iter().zip(&r2.jobs) {
                        assert_eq!(j1.instructions, j2.instructions, "f{fork} t{threads} d{depth}");
                        assert_eq!(j1.cycles, j2.cycles, "f{fork} t{threads} d{depth}");
                        assert_eq!(j1.windows, j2.windows, "f{fork} t{threads} d{depth}");
                    }
                    assert_eq!(r1.stats.batches, r2.stats.batches, "f{fork} t{threads}");
                    assert_eq!(r1.stats.slots, r2.stats.slots, "f{fork} t{threads}");
                    assert_eq!(r1.stats.starved, r2.stats.starved, "f{fork} t{threads}");
                    assert_eq!(r1.stats.filled, r2.stats.filled, "f{fork} t{threads}");
                    assert_eq!(r1.stats.target_batch, r2.stats.target_batch);
                    // Forked runs absorb every handle's served count back
                    // into the parent, so totals match the serial run.
                    assert_eq!(p1.served(), p2.served(), "f{fork} t{threads} d{depth}");
                }
            }
        }
    }

    #[test]
    fn pipelined_engine_handles_empty_and_tiny_jobs() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("xz", 120);
        // More threads than sub-traces, deeper ring than chunks.
        let mut p = TablePredictor::new(8);
        let opts = EngineOptions {
            target_batch: 2,
            encode_threads: 16,
            pipeline_depth: 8,
            fork_predict: true,
        };
        let mut engine = BatchEngine::with_options(&mut p, opts);
        engine.submit(job(&[], &cfg, 4));
        engine.submit(job(&recs, &cfg, 3));
        let report = engine.run().unwrap();
        assert_eq!(report.jobs[0].instructions, 0);
        assert_eq!(report.jobs[1].instructions, 120);
        assert_eq!(report.stats.slots, 120);
        // Threads clamp to the sub-trace count (3 here).
        assert_eq!(report.stats.encode_threads, 3);
        let mut p2 = TablePredictor::new(8);
        let mut serial = BatchEngine::new(&mut p2, 2);
        serial.submit(job(&[], &cfg, 4));
        serial.submit(job(&recs, &cfg, 3));
        let r2 = serial.run().unwrap();
        assert_eq!(report.jobs[1].cycles, r2.jobs[1].cycles);
        assert_eq!(report.jobs[1].windows, r2.jobs[1].windows);
    }

    #[test]
    fn progress_counter_tracks_instructions() {
        // The job server's hand-off hook: a shared counter bumped once
        // per simulated instruction, on the serial and threaded paths.
        let cfg = SimConfig::default_o3();
        let recs = make_records("xz", 1_500);
        for threads in [1usize, 4] {
            let progress = Arc::new(AtomicU64::new(0));
            let mut p = TablePredictor::new(16);
            let opts = EngineOptions { encode_threads: threads, ..EngineOptions::default() };
            let mut engine = BatchEngine::with_options(&mut p, opts);
            engine.submit(JobSpec {
                records: recs.as_slice().into(),
                cfg: &cfg,
                subtraces: 3,
                window: 0,
                cfg_feature: 0.0,
                progress: Some(Arc::clone(&progress)),
            });
            let report = engine.run().unwrap();
            assert_eq!(progress.load(Ordering::Relaxed), report.jobs[0].instructions);
            assert_eq!(report.jobs[0].instructions, 1_500);
        }
    }

    #[test]
    fn pipelined_stats_report_effective_configuration() {
        let cfg = SimConfig::default_o3();
        let recs = make_records("mcf", 2_000);
        let mut p = TablePredictor::new(16);
        let opts = EngineOptions {
            target_batch: 4,
            encode_threads: 2,
            pipeline_depth: 2,
            fork_predict: true,
        };
        let mut engine = BatchEngine::with_options(&mut p, opts);
        engine.submit(job(&recs, &cfg, 8));
        let report = engine.run().unwrap();
        assert_eq!(report.stats.encode_threads, 2);
        assert_eq!(report.stats.pipeline_depth, 2);
        assert!(report.stats.predict_seconds >= 0.0);
        let idle = report.predictor_idle_fraction();
        assert!((0.0..=1.0).contains(&idle), "idle={idle}");
    }
}
