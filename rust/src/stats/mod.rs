//! Statistics and reporting: error metrics, CPI series analysis, the §4.1
//! gaussian accuracy deduction, and plain-text table/series rendering for
//! the paper-reproduction reports.

/// Absolute normalized CPI error (paper §4.1):
/// `|CPI_sim / CPI_ref - 1|`.
pub fn cpi_error(sim_cpi: f64, ref_cpi: f64) -> f64 {
    if ref_cpi == 0.0 {
        return 0.0;
    }
    (sim_cpi / ref_cpi - 1.0).abs()
}

/// Paper §2.5 instruction prediction error: `|pred - y| / (y + 1)`.
pub fn pred_error(pred: f64, actual: f64) -> f64 {
    (pred - actual).abs() / (actual + 1.0)
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Abramowitz & Stegun 7.1.26 erf approximation (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// `E|X - 1|` for `X ~ N(mean, std^2)` — the expected absolute simulation
/// error of a normalized-CPI distribution (paper §4.1 "Accuracy Against
/// Hardware": the SimNet-vs-A64FX deduction).
pub fn expected_abs_error(mean: f64, std: f64) -> f64 {
    if std <= 0.0 {
        return (mean - 1.0).abs();
    }
    let d = (mean - 1.0) / std;
    std * (2.0 / std::f64::consts::PI).sqrt() * (-d * d / 2.0).exp()
        + (mean - 1.0) * (1.0 - 2.0 * phi(-d))
}

/// Product of two independent gaussians' (mean, std) — first-order
/// propagation, as the paper uses for
/// `CPI_SimNet/CPI_gem5 x CPI_gem5/CPI_hw`.
pub fn gaussian_product(m1: f64, s1: f64, m2: f64, s2: f64) -> (f64, f64) {
    let mean = m1 * m2;
    let var = (m1 * s2).powi(2) + (m2 * s1).powi(2) + (s1 * s2).powi(2);
    (mean, var.sqrt())
}

/// Relative-accuracy helper for the §5 case studies: speedup of `new` over
/// `base` in percent.
pub fn speedup_pct(base_cycles: u64, new_cycles: u64) -> f64 {
    if new_cycles == 0 {
        return 0.0;
    }
    (base_cycles as f64 / new_cycles as f64 - 1.0) * 100.0
}

// ---------------------------------------------------------------------
// Plain-text rendering
// ---------------------------------------------------------------------

/// Minimal aligned-column table printer for reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:w$}  ", cell, w = widths[c]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1))));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Render a windowed CPI series as a compact sparkline + stats (Figure 6's
/// textual stand-in).
pub fn render_cpi_series(name: &str, windows: &[(u64, u64)]) -> String {
    if windows.is_empty() {
        return format!("{name}: (no windows)\n");
    }
    let cpis: Vec<f64> = windows
        .iter()
        .map(|(n, c)| if *n == 0 { 0.0 } else { *c as f64 / *n as f64 })
        .collect();
    let lo = cpis.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = cpis.iter().cloned().fold(0.0f64, f64::max);
    let ticks = [
        '\u{2581}', '\u{2582}', '\u{2583}', '\u{2584}', '\u{2585}', '\u{2586}', '\u{2587}',
        '\u{2588}',
    ];
    let spark: String = cpis
        .iter()
        .map(|&c| {
            let t = if hi > lo { (c - lo) / (hi - lo) } else { 0.5 };
            ticks[((t * 7.0).round() as usize).min(7)]
        })
        .collect();
    format!(
        "{name}: mean={:.3} min={lo:.3} max={hi:.3} windows={}\n  {spark}\n",
        mean(&cpis),
        cpis.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpi_error_basics() {
        assert!((cpi_error(1.1, 1.0) - 0.1).abs() < 1e-12);
        assert!((cpi_error(0.9, 1.0) - 0.1).abs() < 1e-12);
        assert_eq!(cpi_error(5.0, 0.0), 0.0);
    }

    #[test]
    fn pred_error_matches_paper_definition() {
        assert!((pred_error(0.0, 0.0) - 0.0).abs() < 1e-12);
        assert!((pred_error(1.0, 0.0) - 1.0).abs() < 1e-12);
        assert!((pred_error(1001.0, 1000.0) - 1.0 / 1001.0).abs() < 1e-9);
    }

    #[test]
    fn erf_reference_points() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
        assert!((phi(0.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn expected_abs_error_paper_numbers() {
        // Paper §4.1: N(1.060, 0.016^2) -> expected absolute error ~6.0%.
        let e = expected_abs_error(1.060, 0.016);
        assert!((e - 0.060).abs() < 0.002, "e={e}");
        // Pure-noise case: N(1, s) -> E|X-1| = s*sqrt(2/pi).
        let e0 = expected_abs_error(1.0, 0.1);
        assert!((e0 - 0.1 * (2.0 / std::f64::consts::PI).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn gaussian_product_matches_paper() {
        // Paper: N(1.062, 0.016^2) x N(1.013, 0.078^2) ~ mean 1.076?? The
        // paper reports mean 1.060 x 1.013 -> we verify the formula itself.
        let (m, s) = gaussian_product(1.062, 0.016, 1.013, 0.078);
        assert!((m - 1.0758).abs() < 1e-3);
        assert!(s > 0.078 && s < 0.09, "s={s}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.345".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn sparkline_render() {
        let s = render_cpi_series("x", &[(100, 100), (100, 200), (100, 400)]);
        assert!(s.contains("mean="));
        assert!(s.contains('\u{2588}'));
    }

    #[test]
    fn speedup_sign() {
        assert!(speedup_pct(110, 100) > 9.9);
        assert!(speedup_pct(100, 110) < 0.0);
    }
}
