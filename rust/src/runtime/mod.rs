//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path (the `xla` crate over xla_extension 0.5.1).
//!
//! Python never runs here: `make artifacts` lowered the JAX/Pallas model to
//! `artifacts/<model>_b<B>.hlo.txt`, and this module compiles those once
//! per process and then serves batched inferences from the coordinator's
//! hot loop.
//!
//! Perf-relevant design (see EXPERIMENTS.md §Perf):
//! * Weights are staged as device-resident `PjRtBuffer`s at load time and
//!   reused by every call (`execute_b`), so the per-inference host→device
//!   traffic is the input batch only.
//! * One executable per batch size (1/8/64/256 by default): batch shapes
//!   are static under PJRT, so the bank picks the best-fitting executable
//!   and pads, instead of recompiling.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::TensorFile;

/// Output width of the hybrid head: 3 latency types x (10 classes + 1
/// regression). Mirror of python/compile/model.py.
pub const HEAD_OUT: usize = 33;
/// Classes per latency type (cycles 0..8 + ">8").
pub const NUM_CLASSES: usize = 10;

/// Parsed `<model>.export` manifest written by `python -m compile.aot`.
#[derive(Debug, Clone)]
pub struct ExportManifest {
    pub model: String,
    pub seq_len: usize,
    pub batches: Vec<usize>,
    pub weights: Vec<String>,
}

impl ExportManifest {
    pub fn read(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let mut model = String::new();
        let mut seq_len = 0usize;
        let mut batches = Vec::new();
        let mut weights: Option<Vec<String>> = None;
        // Each key may appear once. Duplicates used to silently last-win,
        // which made a concatenated/merged manifest load with whichever
        // half came second — reject them naming the path and the key.
        let dup = |key: &str| anyhow!("manifest {}: duplicate `{key}` line", path.display());
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("model") => {
                    if !model.is_empty() {
                        return Err(dup("model"));
                    }
                    model = it.next().unwrap_or("").to_string();
                }
                Some("seq_len") => {
                    if seq_len != 0 {
                        return Err(dup("seq_len"));
                    }
                    let tok = it.next().unwrap_or("");
                    seq_len = tok.parse().map_err(|_| {
                        anyhow!(
                            "manifest {}: bad seq_len {tok:?} in `seq_len` line",
                            path.display()
                        )
                    })?;
                }
                Some("batches") => {
                    if !batches.is_empty() {
                        return Err(dup("batches"));
                    }
                    // A malformed batch size must fail loudly (it used to
                    // be swallowed into batch-size 0, which later selects
                    // executables that do not exist).
                    batches = it
                        .map(|b| match b.parse::<usize>() {
                            Ok(0) | Err(_) => Err(anyhow!(
                                "manifest {}: bad batch size {b:?} in `batches` line",
                                path.display()
                            )),
                            Ok(v) => Ok(v),
                        })
                        .collect::<Result<Vec<usize>>>()?;
                }
                Some("weights") => {
                    if weights.is_some() {
                        return Err(dup("weights"));
                    }
                    weights = Some(it.map(|s| s.to_string()).collect());
                }
                _ => {}
            }
        }
        if model.is_empty() || seq_len == 0 || batches.is_empty() {
            bail!("malformed manifest {}", path.display());
        }
        Ok(ExportManifest { model, seq_len, batches, weights: weights.unwrap_or_default() })
    }
}

/// Decode mode of a trained model (from `<model>.meta`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputMode {
    /// Hybrid classification + regression (paper's "hyb").
    Hybrid,
    /// Regression heads only (paper's "reg").
    Regression,
}

/// One compiled executable at a fixed batch size.
struct BatchExecutable {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// A loaded model: PJRT client + per-batch-size executables + weights
/// staged on device.
pub struct ModelBank {
    client: xla::PjRtClient,
    manifest: ExportManifest,
    exes: Vec<BatchExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub mode: OutputMode,
    /// Cumulative inferences served (for throughput reports).
    pub inferences: u64,
    /// Cumulative execute calls (batches) served.
    pub calls: u64,
}

impl ModelBank {
    /// Load `model` from `dir`: manifest + HLO artifacts + weights.
    /// `weights_file`: explicit `.smw`; defaults to `<model>.smw` if
    /// present, else `<model>.init.smw`.
    pub fn load(dir: &Path, model: &str, weights_file: Option<&Path>) -> Result<Self> {
        let manifest = ExportManifest::read(&dir.join(format!("{model}.export")))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

        let weights_path: PathBuf = match weights_file {
            Some(p) => p.to_path_buf(),
            None => {
                let trained = dir.join(format!("{model}.smw"));
                if trained.exists() {
                    trained
                } else {
                    dir.join(format!("{model}.init.smw"))
                }
            }
        };
        let tensors = TensorFile::read(&weights_path)
            .with_context(|| format!("reading weights {}", weights_path.display()))?;
        if tensors.tensors.len() != manifest.weights.len() {
            bail!(
                "weight count mismatch: {} in {}, manifest expects {}",
                tensors.tensors.len(),
                weights_path.display(),
                manifest.weights.len()
            );
        }
        let mut weight_bufs = Vec::with_capacity(tensors.tensors.len());
        for (t, expect) in tensors.tensors.iter().zip(&manifest.weights) {
            if &t.name != expect {
                bail!("weight order mismatch: got {}, expected {}", t.name, expect);
            }
            let buf = client
                .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                .map_err(|e| anyhow!("staging weight {}: {e:?}", t.name))?;
            weight_bufs.push(buf);
        }

        let mut exes = Vec::new();
        for &b in &manifest.batches {
            let path = dir.join(format!("{model}_b{b}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling b={b}: {e:?}"))?;
            exes.push(BatchExecutable { exe, batch: b });
        }
        exes.sort_by_key(|e| e.batch);

        let mode = read_model_mode(dir, model).unwrap_or(OutputMode::Hybrid);
        Ok(ModelBank { client, manifest, exes, weight_bufs, mode, inferences: 0, calls: 0 })
    }

    pub fn seq_len(&self) -> usize {
        self.manifest.seq_len
    }

    /// Architecture name from the export manifest.
    pub fn model_name(&self) -> &str {
        &self.manifest.model
    }

    /// Input floats per encoded instruction sequence.
    pub fn input_width(&self) -> usize {
        self.manifest.seq_len * crate::features::NUM_FEATURES
    }

    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        self.exes.last().map(|e| e.batch).unwrap_or(1)
    }

    /// Run the model over `n` encoded inputs packed in `inputs` (length >=
    /// n * input_width); appends `n` rows of `HEAD_OUT` floats to `out`.
    /// Chunks and pads to the compiled batch sizes.
    pub fn infer_raw(&mut self, inputs: &[f32], n: usize, out: &mut Vec<f32>) -> Result<()> {
        let width = self.input_width();
        debug_assert!(inputs.len() >= n * width);
        let mut done = 0;
        while done < n {
            let remaining = n - done;
            // Smallest compiled batch that fits, else the largest.
            let idx = self
                .exes
                .iter()
                .position(|e| e.batch >= remaining)
                .unwrap_or(self.exes.len() - 1);
            let b = self.exes[idx].batch;
            let take = remaining.min(b);
            let chunk = &inputs[done * width..(done + take) * width];
            let rows = self.execute_chunk(idx, chunk, take, b)?;
            out.extend_from_slice(&rows);
            done += take;
            self.calls += 1;
        }
        self.inferences += n as u64;
        Ok(())
    }

    fn execute_chunk(
        &self,
        exe_idx: usize,
        chunk: &[f32],
        take: usize,
        batch: usize,
    ) -> Result<Vec<f32>> {
        let seq = self.manifest.seq_len;
        let nfeat = crate::features::NUM_FEATURES;
        // Pad the batch dimension if needed.
        let padded;
        let data: &[f32] = if take == batch {
            chunk
        } else {
            let mut v = vec![0.0f32; batch * seq * nfeat];
            v[..chunk.len()].copy_from_slice(chunk);
            padded = v;
            &padded
        };
        let input = self
            .client
            .buffer_from_host_buffer::<f32>(data, &[batch, seq, nfeat], None)
            .map_err(|e| anyhow!("staging input: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&input);
        let result =
            self.exes[exe_idx].exe.execute_b(&args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let vals = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(vals[..take * HEAD_OUT].to_vec())
    }
}

/// Read the decode mode from `<model>.meta` (written by train.py).
/// Shared by the PJRT [`ModelBank`] and the native backend so both decode
/// a trained model the same way.
pub(crate) fn read_model_mode(dir: &Path, model: &str) -> Option<OutputMode> {
    let text = std::fs::read_to_string(dir.join(format!("{model}.meta"))).ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("mode ") {
            return Some(if rest.trim() == "reg" {
                OutputMode::Regression
            } else {
                OutputMode::Hybrid
            });
        }
    }
    None
}

/// Decode one `HEAD_OUT`-float row to (fetch, exec, store) latencies using
/// the hybrid rule (paper §2.3) — identical to python `decode_latency`.
pub fn decode_row(row: &[f32], mode: OutputMode) -> (u32, u32, u32) {
    let mut lats = [0u32; 3];
    for (t, lat) in lats.iter_mut().enumerate() {
        let base = t * (NUM_CLASSES + 1);
        let reg = (row[base + NUM_CLASSES] * crate::features::LAT_SCALE).max(0.0);
        *lat = match mode {
            OutputMode::Regression => reg.round() as u32,
            OutputMode::Hybrid => {
                let logits = &row[base..base + NUM_CLASSES];
                let cls = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if cls < NUM_CLASSES - 1 {
                    cls as u32
                } else {
                    (reg.round() as u32).max((NUM_CLASSES - 1) as u32)
                }
            }
        };
    }
    (lats[0], lats[1], lats[2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_hybrid_picks_class() {
        let mut row = vec![0.0f32; HEAD_OUT];
        row[3] = 5.0; // F class 3
        row[11 + 9] = 5.0; // E ">8"
        row[11 + 10] = 100.0 / crate::features::LAT_SCALE; // E regression
        row[22] = 5.0; // S class 0
        let (f, e, s) = decode_row(&row, OutputMode::Hybrid);
        assert_eq!(f, 3);
        assert_eq!(e, 100);
        assert_eq!(s, 0);
    }

    #[test]
    fn decode_regression_ignores_classes() {
        let mut row = vec![0.0f32; HEAD_OUT];
        row[0] = 99.0; // class logits ignored in reg mode
        row[10] = 2.0 / crate::features::LAT_SCALE;
        row[21] = 7.4 / crate::features::LAT_SCALE;
        row[32] = 0.0;
        let (f, e, s) = decode_row(&row, OutputMode::Regression);
        assert_eq!(f, 2);
        assert_eq!(e, 7);
        assert_eq!(s, 0);
    }

    #[test]
    fn decode_hybrid_overflow_class_floors_at_9() {
        let mut row = vec![0.0f32; HEAD_OUT];
        row[9] = 5.0; // ">8" class wins
        row[10] = 0.0; // regression says 0 — decode must still be >= 9
        let (f, _, _) = decode_row(&row, OutputMode::Hybrid);
        assert_eq!(f, 9);
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("simnet_runtime_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.export");
        std::fs::write(&p, "model c3\nseq_len 32\nbatches 1 8 64\nweights a b c\n").unwrap();
        let m = ExportManifest::read(&p).unwrap();
        assert_eq!(m.model, "c3");
        assert_eq!(m.seq_len, 32);
        assert_eq!(m.batches, vec![1, 8, 64]);
        assert_eq!(m.weights, vec!["a", "b", "c"]);
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = std::env::temp_dir().join("simnet_runtime_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.export");
        std::fs::write(&p, "hello world\n").unwrap();
        assert!(ExportManifest::read(&p).is_err());
    }

    #[test]
    fn manifest_rejects_unparseable_batch_size_naming_token() {
        let dir = std::env::temp_dir().join("simnet_runtime_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("badbatch.export");
        std::fs::write(&p, "model c3\nseq_len 32\nbatches 1 x8 64\nweights a\n").unwrap();
        let err = ExportManifest::read(&p).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("x8"), "error must name the offending token: {msg}");
        assert!(msg.contains("batch size"), "error must say what is wrong: {msg}");
    }

    #[test]
    fn manifest_rejects_duplicate_keys_naming_path_and_key() {
        let dir = std::env::temp_dir().join("simnet_runtime_tests");
        std::fs::create_dir_all(&dir).unwrap();
        for (key, content) in [
            ("model", "model c3\nmodel c1\nseq_len 32\nbatches 1\nweights a\n"),
            ("seq_len", "model c3\nseq_len 32\nseq_len 16\nbatches 1\nweights a\n"),
            ("batches", "model c3\nseq_len 32\nbatches 1\nbatches 2\nweights a\n"),
            ("weights", "model c3\nseq_len 32\nbatches 1\nweights a\nweights b\n"),
        ] {
            let p = dir.join(format!("dup_{key}.export"));
            std::fs::write(&p, content).unwrap();
            let err = ExportManifest::read(&p).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("duplicate"), "[{key}] must be rejected as duplicate: {msg}");
            assert!(msg.contains(&format!("`{key}`")), "[{key}] error must name the key: {msg}");
            assert!(
                msg.contains(&format!("dup_{key}.export")),
                "[{key}] error must name the path: {msg}"
            );
        }
    }

    #[test]
    fn manifest_bad_seq_len_names_path_and_token() {
        let dir = std::env::temp_dir().join("simnet_runtime_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("badseq.export");
        std::fs::write(&p, "model c3\nseq_len x32\nbatches 1\nweights a\n").unwrap();
        let err = ExportManifest::read(&p).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("x32"), "error must name the offending token: {msg}");
        assert!(msg.contains("badseq.export"), "error must name the path: {msg}");
        assert!(msg.contains("seq_len"), "error must say which key: {msg}");
    }

    #[test]
    fn manifest_rejects_zero_batch_size() {
        let dir = std::env::temp_dir().join("simnet_runtime_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("zerobatch.export");
        std::fs::write(&p, "model c3\nseq_len 32\nbatches 0 8\nweights a\n").unwrap();
        let err = ExportManifest::read(&p).unwrap_err();
        assert!(format!("{err}").contains("\"0\""), "zero batch must be rejected: {err}");
    }
}
