//! Architectural register file layout.
//!
//! 32 integer registers (x0..x31) followed by 32 FP/SIMD registers
//! (v0..v31), as in ARMv8. Register ids are flat indices into this space;
//! `REG_NONE` marks an unused operand slot.

/// Flat architectural register id.
pub type RegId = i8;

/// Number of integer registers.
pub const INT_REGS: usize = 32;
/// Number of FP/SIMD registers.
pub const SIMD_REGS: usize = 32;
/// Total architectural registers.
pub const NUM_REGS: usize = INT_REGS + SIMD_REGS;

/// Sentinel for an unused register slot.
pub const REG_NONE: RegId = -1;

/// First FP/SIMD register id.
pub const FIRST_SIMD_REG: RegId = INT_REGS as RegId;

/// Stack pointer (by convention x31).
pub const REG_SP: RegId = 31;
/// Link register (by convention x30).
pub const REG_LR: RegId = 30;

/// Whether a register id addresses the FP/SIMD file.
#[inline]
pub fn is_simd_reg(r: RegId) -> bool {
    r >= FIRST_SIMD_REG
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_split() {
        assert!(!is_simd_reg(0));
        assert!(!is_simd_reg(REG_SP));
        assert!(is_simd_reg(FIRST_SIMD_REG));
        assert!(is_simd_reg((NUM_REGS - 1) as RegId));
        assert_eq!(NUM_REGS, 64);
    }
}
