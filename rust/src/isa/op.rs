//! Operation classes and functional-unit classes.

/// Operation class of an instruction. Mirrors the granularity gem5's O3 CPU
/// uses for scheduling (`OpClass` in gem5), which is also the granularity
/// the SimNet feature encoding needs: enough to derive functional-unit
/// competition, memory behaviour, and control-flow behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum OpClass {
    /// Simple integer ALU op (add/sub/logic/shift/compare).
    IntAlu = 0,
    /// Integer multiply.
    IntMult = 1,
    /// Integer divide (long latency, unpipelined).
    IntDiv = 2,
    /// FP add/sub/convert/compare.
    FloatAdd = 3,
    /// FP multiply / fused multiply-add.
    FloatMult = 4,
    /// FP divide (long latency, unpipelined).
    FloatDiv = 5,
    /// FP square root (long latency, unpipelined).
    FloatSqrt = 6,
    /// SIMD integer/logical op.
    SimdAlu = 7,
    /// SIMD multiply / FMA.
    SimdMult = 8,
    /// Memory read.
    Load = 9,
    /// Memory write.
    Store = 10,
    /// Conditional direct branch.
    CondBranch = 11,
    /// Unconditional direct jump.
    Jump = 12,
    /// Indirect branch (target from register).
    IndirectBranch = 13,
    /// Direct call (pushes return address).
    Call = 14,
    /// Return (indirect, predicted by RAS).
    Ret = 15,
    /// Memory barrier (orders loads/stores).
    MemBarrier = 16,
    /// Serializing instruction (drains the pipeline, e.g. system ops).
    Serialize = 17,
    /// No-op.
    Nop = 18,
}

/// Total number of op classes (for encoding / histogram arrays).
pub const NUM_OP_CLASSES: usize = 19;

/// Functional-unit class an op issues to. The DES models per-FU-class issue
/// ports and occupancy; the feature encoding exposes the class so the model
/// can learn structural-hazard competition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum FuClass {
    IntAlu = 0,
    IntMulDiv = 1,
    FpAlu = 2,
    FpMulDiv = 3,
    Simd = 4,
    LoadPort = 5,
    StorePort = 6,
    Branch = 7,
    None = 8,
}

/// Number of functional-unit classes.
pub const NUM_FU_CLASSES: usize = 9;

impl OpClass {
    /// All op classes, in discriminant order.
    pub const ALL: [OpClass; NUM_OP_CLASSES] = [
        OpClass::IntAlu,
        OpClass::IntMult,
        OpClass::IntDiv,
        OpClass::FloatAdd,
        OpClass::FloatMult,
        OpClass::FloatDiv,
        OpClass::FloatSqrt,
        OpClass::SimdAlu,
        OpClass::SimdMult,
        OpClass::Load,
        OpClass::Store,
        OpClass::CondBranch,
        OpClass::Jump,
        OpClass::IndirectBranch,
        OpClass::Call,
        OpClass::Ret,
        OpClass::MemBarrier,
        OpClass::Serialize,
        OpClass::Nop,
    ];

    /// Functional unit this op class issues to.
    pub fn fu_class(self) -> FuClass {
        use OpClass::*;
        match self {
            IntAlu => FuClass::IntAlu,
            IntMult | IntDiv => FuClass::IntMulDiv,
            FloatAdd => FuClass::FpAlu,
            FloatMult | FloatDiv | FloatSqrt => FuClass::FpMulDiv,
            SimdAlu | SimdMult => FuClass::Simd,
            Load => FuClass::LoadPort,
            Store => FuClass::StorePort,
            CondBranch | Jump | IndirectBranch | Call | Ret => FuClass::Branch,
            MemBarrier | Serialize | Nop => FuClass::None,
        }
    }

    /// Nominal execution latency in cycles on its functional unit (hit
    /// latencies for memory ops are added by the cache model instead).
    pub fn exec_latency(self) -> u32 {
        use OpClass::*;
        match self {
            IntAlu => 1,
            IntMult => 3,
            IntDiv => 12,
            FloatAdd => 2,
            FloatMult => 4,
            FloatDiv => 12,
            FloatSqrt => 20,
            SimdAlu => 2,
            SimdMult => 4,
            Load => 1,  // address generation; memory latency added separately
            Store => 1, // address generation + data
            CondBranch | Jump | IndirectBranch | Call | Ret => 1,
            MemBarrier | Serialize => 1,
            Nop => 1,
        }
    }

    /// Whether the FU is pipelined (can accept a new op every cycle).
    pub fn fu_pipelined(self) -> bool {
        !matches!(self, OpClass::IntDiv | OpClass::FloatDiv | OpClass::FloatSqrt)
    }

    #[inline]
    pub fn is_load(self) -> bool {
        self == OpClass::Load
    }

    #[inline]
    pub fn is_store(self) -> bool {
        self == OpClass::Store
    }

    /// Any memory-referencing op.
    #[inline]
    pub fn is_mem(self) -> bool {
        self.is_load() || self.is_store()
    }

    /// Any control-flow op.
    #[inline]
    pub fn is_control(self) -> bool {
        use OpClass::*;
        matches!(self, CondBranch | Jump | IndirectBranch | Call | Ret)
    }

    /// Conditional direct branch.
    #[inline]
    pub fn is_cond_branch(self) -> bool {
        self == OpClass::CondBranch
    }

    /// Control flow whose target comes from a register (BTB/RAS-predicted).
    #[inline]
    pub fn is_indirect(self) -> bool {
        matches!(self, OpClass::IndirectBranch | OpClass::Ret)
    }

    /// Memory barrier.
    #[inline]
    pub fn is_barrier(self) -> bool {
        self == OpClass::MemBarrier
    }

    /// Pipeline-serializing op.
    #[inline]
    pub fn is_serializing(self) -> bool {
        self == OpClass::Serialize
    }

    /// Floating-point op (scalar).
    #[inline]
    pub fn is_fp(self) -> bool {
        use OpClass::*;
        matches!(self, FloatAdd | FloatMult | FloatDiv | FloatSqrt)
    }

    /// SIMD op.
    #[inline]
    pub fn is_simd(self) -> bool {
        matches!(self, OpClass::SimdAlu | OpClass::SimdMult)
    }

    /// Stable small integer id (used directly in the feature encoding).
    #[inline]
    pub fn code(self) -> u8 {
        self as u8
    }

    /// Inverse of [`OpClass::code`]. Panics on out-of-range input.
    pub fn from_code(code: u8) -> OpClass {
        Self::ALL[code as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_roundtrip() {
        for op in OpClass::ALL {
            assert_eq!(OpClass::from_code(op.code()), op);
        }
    }

    #[test]
    fn control_flags_consistent() {
        for op in OpClass::ALL {
            if op.is_cond_branch() || op.is_indirect() {
                assert!(op.is_control());
            }
            if op.is_mem() {
                assert!(!op.is_control());
            }
        }
    }

    #[test]
    fn long_latency_ops_unpipelined() {
        assert!(!OpClass::IntDiv.fu_pipelined());
        assert!(!OpClass::FloatSqrt.fu_pipelined());
        assert!(OpClass::IntAlu.fu_pipelined());
        assert!(OpClass::Load.fu_pipelined());
    }

    #[test]
    fn fu_mapping_total() {
        // Every op class maps to some FU class and a nonzero latency.
        for op in OpClass::ALL {
            let _ = op.fu_class();
            assert!(op.exec_latency() >= 1);
        }
    }
}
