//! Synthetic ARMv8-like instruction set.
//!
//! SimNet is ISA-agnostic at the framework level: the predictor consumes
//! *static instruction properties* (paper Table 1, top row) rather than raw
//! encodings. This module defines a synthetic RISC ISA rich enough to
//! exercise every feature the paper lists — operation class, direct/indirect
//! branches, memory barriers, serializing ops, up to 8 source and 6
//! destination registers, and memory accesses with sizes — without carrying
//! a real decoder.

mod op;
mod regs;

pub use op::{FuClass, OpClass};
pub use regs::{
    is_simd_reg, RegId, FIRST_SIMD_REG, INT_REGS, NUM_REGS, REG_LR, REG_NONE, REG_SP, SIMD_REGS,
};

/// Maximum number of source registers per instruction (paper: 8).
pub const MAX_SRC_REGS: usize = 8;
/// Maximum number of destination registers per instruction (paper: 6).
pub const MAX_DST_REGS: usize = 6;

/// A single *dynamic* instruction instance: the static properties plus the
/// resolved dynamic facts (effective address, branch outcome) produced by
/// functional execution of a [`crate::workload::Program`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Inst {
    /// Program counter of this instruction.
    pub pc: u64,
    /// Operation class (determines functional unit, latency class, flags).
    pub op: OpClass,
    /// Source register ids; `REG_NONE` marks unused slots.
    pub srcs: [RegId; MAX_SRC_REGS],
    /// Destination register ids; `REG_NONE` marks unused slots.
    pub dsts: [RegId; MAX_DST_REGS],
    /// Effective data address for loads/stores (0 otherwise).
    pub mem_addr: u64,
    /// Access size in bytes for loads/stores (0 otherwise).
    pub mem_size: u8,
    /// Branch target (resolved) for control-flow ops; 0 otherwise.
    pub target: u64,
    /// Whether a conditional branch was actually taken (always true for
    /// unconditional control flow).
    pub taken: bool,
}

impl Default for Inst {
    fn default() -> Self {
        Inst {
            pc: 0,
            op: OpClass::Nop,
            srcs: [REG_NONE; MAX_SRC_REGS],
            dsts: [REG_NONE; MAX_DST_REGS],
            mem_addr: 0,
            mem_size: 0,
            target: 0,
            taken: false,
        }
    }
}

impl Inst {
    /// True for any instruction that reads memory.
    #[inline]
    pub fn is_load(&self) -> bool {
        self.op.is_load()
    }

    /// True for any instruction that writes memory.
    #[inline]
    pub fn is_store(&self) -> bool {
        self.op.is_store()
    }

    /// True for any control-flow instruction.
    #[inline]
    pub fn is_control(&self) -> bool {
        self.op.is_control()
    }

    /// Number of populated source registers.
    pub fn num_srcs(&self) -> usize {
        self.srcs.iter().filter(|&&r| r != REG_NONE).count()
    }

    /// Number of populated destination registers.
    pub fn num_dsts(&self) -> usize {
        self.dsts.iter().filter(|&&r| r != REG_NONE).count()
    }

    /// Cache-line address (64B lines) of the instruction fetch.
    #[inline]
    pub fn fetch_line(&self) -> u64 {
        self.pc >> 6
    }

    /// Cache-line address (64B lines) of the data access, if any.
    #[inline]
    pub fn data_line(&self) -> u64 {
        self.mem_addr >> 6
    }

    /// 4KiB page of the data access, if any.
    #[inline]
    pub fn data_page(&self) -> u64 {
        self.mem_addr >> 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_inst_is_nop() {
        let i = Inst::default();
        assert_eq!(i.op, OpClass::Nop);
        assert_eq!(i.num_srcs(), 0);
        assert_eq!(i.num_dsts(), 0);
        assert!(!i.is_load() && !i.is_store() && !i.is_control());
    }

    #[test]
    fn line_and_page_math() {
        let i = Inst { pc: 0x1040, mem_addr: 0x2345, mem_size: 8, ..Default::default() };
        assert_eq!(i.fetch_line(), 0x1040 >> 6);
        assert_eq!(i.data_line(), 0x2345 >> 6);
        assert_eq!(i.data_page(), 0x2);
    }

    #[test]
    fn src_dst_counting() {
        let mut i = Inst::default();
        i.srcs[0] = 3;
        i.srcs[1] = 17;
        i.dsts[0] = 5;
        assert_eq!(i.num_srcs(), 2);
        assert_eq!(i.num_dsts(), 1);
    }
}
