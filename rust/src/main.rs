//! `repro` — the SimNet-RS command-line launcher.
//!
//! Subcommands map 1:1 onto the workflows of the paper:
//!
//! ```text
//! gen-trace      run the reference DES over a benchmark, dump a .smt trace
//! gen-dataset    run the DES over the training benchmarks, build a .smd
//! simulate-des   DES-only run (CPI + throughput)
//! simulate-ml    ML simulation of a benchmark (sequential/parallel/pooled)
//! serve          resident job server (warm predictors, co-batched tenants)
//! submit         send a simulation job to a running server
//! status         query a job (or the whole server) by id
//! shutdown       drain and stop a running server
//! report         table4 | fig5 | fig6 | fig10 | attribution
//! sweep          subtrace-size | subtraces | workers | branch-predictor |
//!                l2-size | rob-size
//! list-benches   show the 25-benchmark suite
//! ```
//!
//! Hand-rolled argument parsing (clap is not vendored in this image); every
//! flag is `--key value`. Each subcommand rejects flags it does not accept,
//! naming the ones it does — the accepted sets all live in one
//! [`FLAG_TABLE`]. All ML-simulation runs are constructed through
//! [`simnet::api::Simulation`]; `simulate-ml --json PATH` writes the run's
//! [`simnet::api::SimReport`] as JSON, and `submit` ships the same run
//! description to a `serve` daemon as a [`simnet::api::job::JobRequest`].

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use simnet::api::job::{ConfigSpec, JobRequest, JobSource, Priority};
use simnet::api::{Backend, PredictorSpec, SimReport, Simulation, WeightsSource};
use simnet::coordinator::EngineOptions;
use simnet::des::{simulate, SimConfig};
use simnet::reports::{self, attribution, figs, sweeps, table4};
use simnet::server::json::Value;
use simnet::server::{protocol, JobServer, ServerOptions};
use simnet::trace::{build_dataset, DatasetOptions, TraceRecord, TraceWriter};
use simnet::workload::{find, suite, training_set};

/// Flags every simulation-flavored subcommand shares (machine config).
const CONFIG_FLAGS: &[&str] = &["config", "bp", "l2-kb", "rob"];

/// Flags that select a predictor ([`predictor_spec_from`]).
const PREDICTOR_FLAGS: &[&str] = &["table", "seq", "model", "weights", "artifacts", "backend"];

/// Run-shaping flags `simulate-ml` and `submit` share (source selection
/// and the execution knobs of a [`Simulation`] / [`JobRequest`]).
const RUN_FLAGS: &[&str] = &[
    "bench",
    "n",
    "trace",
    "input-seed",
    "subtraces",
    "workers",
    "window",
    "target-batch",
    "encode-threads",
    "pipeline-depth",
    "no-fork-predict",
    "no-mmap",
    "streaming",
];

/// The accepted flag sets of every subcommand (report/sweep variants are
/// keyed as `"report fig5"`-style compound names), resolved through
/// [`check_flags_for`] — one table instead of an inline list at each
/// call site.
const FLAG_TABLE: &[(&str, &[&[&str]])] = &[
    ("list-benches", &[]),
    ("gen-trace", &[CONFIG_FLAGS, &["bench", "n", "out", "input-seed"]]),
    (
        "gen-dataset",
        &[CONFIG_FLAGS, &["out", "benches", "n-per", "seq", "limit", "context", "rob-mix"]],
    ),
    ("simulate-des", &[CONFIG_FLAGS, &["bench", "n", "input-seed"]]),
    ("simulate-ml", &[CONFIG_FLAGS, PREDICTOR_FLAGS, RUN_FLAGS, &["json"]]),
    ("serve", &[&["addr", "queue-cap", "max-cobatch", "quiet"]]),
    (
        "submit",
        &[CONFIG_FLAGS, PREDICTOR_FLAGS, RUN_FLAGS, &["addr", "priority", "follow", "json"]],
    ),
    ("status", &[&["addr", "id", "wait", "json"]]),
    ("shutdown", &[&["addr"]]),
    ("report table4", &[CONFIG_FLAGS, &["models", "n", "subtrace", "artifacts"]]),
    (
        "report fig5",
        &[
            CONFIG_FLAGS,
            &["table", "seq", "models", "artifacts", "backend", "n", "benches", "subtrace"],
        ],
    ),
    (
        "report fig6",
        &[
            CONFIG_FLAGS,
            &["table", "seq", "models", "artifacts", "backend", "n", "benches", "window"],
        ],
    ),
    ("report fig10", &[CONFIG_FLAGS, &["models", "bench", "artifacts", "n", "subtrace"]]),
    ("report attribution", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["samples", "benches", "n"]]),
    ("report dataset-size", &[CONFIG_FLAGS, &["artifacts", "n"]]),
    ("sweep subtrace-size", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["n", "benches", "sizes"]]),
    ("sweep l2-size", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["n", "benches", "sizes"]]),
    ("sweep rob-size", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["n", "benches", "sizes"]]),
    ("sweep subtraces", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["n", "counts", "bench"]]),
    ("sweep workers", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["n", "counts", "subtraces", "bench"]]),
    ("sweep branch-predictor", &[CONFIG_FLAGS, PREDICTOR_FLAGS, &["n", "benches"]]),
];

/// Look `cmd` up in [`FLAG_TABLE`] and reject any flag outside its
/// accepted set, listing the accepted ones.
fn check_flags_for(args: &Args, cmd: &str) -> Result<()> {
    let allowed = FLAG_TABLE
        .iter()
        .find(|(c, _)| *c == cmd)
        .map(|(_, a)| *a)
        .unwrap_or_else(|| unreachable!("no FLAG_TABLE entry for {cmd}"));
    args.check_flags(cmd, allowed)
}

/// Parsed `--key value` flags plus positional words.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad value {v}")),
        }
    }

    /// Boolean flag: absent uses `default`; bare `--key` (the parser
    /// gives it the value "true") or `--key true` is true; `--key false`
    /// is false; anything else is a named error.
    fn bool_flag(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => Err(anyhow!("--{key}: bad value {v} (true|false)")),
        }
    }

    fn list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Comma-separated numeric list; a malformed element is a clean CLI
    /// error (`--key: bad value v`), never a panic.
    fn num_list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>> {
        match self.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .map(|s| s.trim().parse().map_err(|_| anyhow!("--{key}: bad value {s}")))
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    /// Reject flags the subcommand does not accept, listing the accepted
    /// set (pre-API the parser silently ignored unknown `--flags`, so a
    /// typo like `--subtrace` ran with the default and no warning).
    fn check_flags(&self, cmd: &str, allowed: &[&[&str]]) -> Result<()> {
        let allowed: Vec<&str> = allowed.concat();
        let mut unknown: Vec<&str> =
            self.flags.keys().map(|k| k.as_str()).filter(|k| !allowed.contains(k)).collect();
        if unknown.is_empty() {
            return Ok(());
        }
        unknown.sort_unstable();
        let mut accepted: Vec<String> = allowed.iter().map(|f| format!("--{f}")).collect();
        accepted.sort_unstable();
        bail!(
            "unknown flag{} --{} for `{cmd}`; accepted: {}",
            if unknown.len() > 1 { "s" } else { "" },
            unknown.join(", --"),
            if accepted.is_empty() { "(none)".to_string() } else { accepted.join(" ") }
        )
    }
}

/// Capture the machine-config flags (--config o3|a64fx, --bp
/// bimode|bimode-l|tage, --l2-kb N, --rob N) as a [`ConfigSpec`] — the
/// serializable form a [`JobRequest`] carries over the wire. Validated
/// eagerly so a bad name fails here, with the flag context, not on the
/// server.
fn config_spec_from(args: &Args) -> Result<ConfigSpec> {
    let spec = ConfigSpec {
        base: args.get("config").unwrap_or("o3").to_string(),
        bp: args.get("bp").map(str::to_string),
        l2_kb: match args.get("l2-kb") {
            None => None,
            Some(kb) => Some(kb.parse::<u64>().context("--l2-kb")?),
        },
        rob: match args.get("rob") {
            None => None,
            Some(rob) => Some(rob.parse::<usize>().context("--rob")?),
        },
    };
    spec.build()?;
    Ok(spec)
}

/// Build a SimConfig from the common machine-config flags.
fn config_from(args: &Args) -> Result<SimConfig> {
    config_spec_from(args)?.build()
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts").unwrap_or("artifacts"))
}

/// Parse `--backend pjrt|native` (default: pjrt).
fn backend_from(args: &Args) -> Result<Backend> {
    match args.get("backend").unwrap_or("pjrt") {
        "pjrt" => Ok(Backend::Pjrt),
        "native" => Ok(Backend::Native),
        other => bail!("unknown --backend {other} (pjrt|native)"),
    }
}

/// Reject predictor-flag mixes that would silently shadow each other:
/// `--table` with any ML-only flag, or `--seq` outside the predictors
/// that take one (`--table`, and `--backend native` where it is the
/// fallback for manifest-free runs). Shared by [`predictor_spec_from`]
/// and [`report_specs`].
fn reject_predictor_conflicts(args: &Args, ml_flags: &[&str]) -> Result<()> {
    if args.get("table").is_some() {
        for f in ml_flags {
            if args.get(f).is_some() {
                bail!("--table conflicts with --{f} (the analytical predictor takes only --seq)");
            }
        }
    } else if args.get("seq").is_some() && !matches!(args.get("backend"), Some("native")) {
        bail!(
            "--seq only applies to --table or --backend native \
             (PJRT models fix their own sequence length)"
        );
    }
    Ok(())
}

/// Predictor spec from flags: --table (analytical) or --model NAME
/// [--backend pjrt|native] [--weights PATH|init]. An explicit `--weights`
/// path that does not exist is an error (it used to fall back silently to
/// init weights) on both ML backends, and mixing --table with the
/// ML-only flags is rejected instead of silently ignoring them.
fn predictor_spec_from(args: &Args, default_model: &str) -> Result<PredictorSpec> {
    reject_predictor_conflicts(args, &["model", "weights", "artifacts", "backend"])?;
    if args.get("table").is_some() {
        return Ok(PredictorSpec::table(args.num("seq", 32usize)?));
    }
    let tag = args.get("model").unwrap_or(default_model);
    let artifacts = artifacts_dir(args);
    let mut spec = match backend_from(args)? {
        Backend::Pjrt => PredictorSpec::ml(&artifacts, tag),
        Backend::Native => PredictorSpec::native(&artifacts, tag, args.num("seq", 32usize)?),
    };
    let mut has_explicit = false;
    match args.get("weights") {
        // `--weights init` forces init weights (the explicit spelling of
        // what a missing-weights run falls back to).
        Some("init") => spec = spec.with_weights_source(WeightsSource::Init),
        Some(path) => {
            spec = spec.with_weights(PathBuf::from(path));
            has_explicit = true;
        }
        None => {}
    }
    if has_explicit {
        // Fail now, with the flag named: a mistyped --weights must
        // never fall back silently to init weights.
        spec.validate().context("--weights")?;
    } else {
        // Still validate eagerly (e.g. unsupported native architecture)
        // so the error surfaces before any trace generation.
        spec.validate()?;
    }
    Ok(spec)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "gen-trace" => cmd_gen_trace(&args),
        "gen-dataset" => cmd_gen_dataset(&args),
        "simulate-des" => cmd_simulate_des(&args),
        "simulate-ml" => cmd_simulate_ml(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "status" => cmd_status(&args),
        "shutdown" => cmd_shutdown(&args),
        "report" => cmd_report(&args),
        "sweep" => cmd_sweep(&args),
        "list-benches" => cmd_list_benches(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other}; run `repro help`"),
    }
}

fn print_usage() {
    println!(
        "repro — SimNet reproduction (rust + JAX + Pallas via PJRT)\n\n\
         USAGE: repro <command> [--flags]\n\n\
         COMMANDS\n\
         \x20 gen-trace    --bench NAME --n N --out trace.smt [--config o3|a64fx] [--input-seed K]\n\
         \x20 gen-dataset  --out data.smd [--benches a,b,c] [--n-per N] [--seq S] [--limit L]\n\
         \x20 simulate-des --bench NAME --n N [--config ...]\n\
         \x20 simulate-ml  --bench NAME --n N [--model c3] [--table] [--backend pjrt|native]\n\
         \x20              [--weights W.smw|init] [--seq S] [--subtraces S] [--workers W]\n\
         \x20              [--target-batch B] [--encode-threads T] [--pipeline-depth D]\n\
         \x20              [--no-fork-predict]\n\
         \x20              [--trace file.smt] [--no-mmap] [--streaming true|false]\n\
         \x20              [--artifacts DIR] [--window W] [--json out.json]\n\
         \x20 serve        [--addr 127.0.0.1:7878] [--queue-cap N] [--max-cobatch N] [--quiet]\n\
         \x20 submit       --bench NAME --n N [simulate-ml flags] [--addr A] [--priority normal|high]\n\
         \x20              [--follow] [--json out.json]\n\
         \x20 status       [--addr A] [--id N [--wait] [--json out.json]]\n\
         \x20 shutdown     [--addr A]\n\
         \x20 report       table4|fig5|fig6|fig10|attribution [--models a,b] [--n N] [--benches ...]\n\
         \x20 sweep        subtrace-size|subtraces|workers|branch-predictor|l2-size|rob-size [...]\n\
         \x20 list-benches\n\n\
         Each subcommand rejects flags it does not accept and lists the accepted set."
    );
}

fn cmd_list_benches(args: &Args) -> Result<()> {
    check_flags_for(args, "list-benches")?;
    let mut t = simnet::stats::Table::new(&["benchmark", "category", "set"]);
    for b in suite() {
        t.row(vec![
            b.name.to_string(),
            format!("{:?}", b.category),
            if b.training { "ML(train)".into() } else { "simulation".into() },
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> Result<()> {
    check_flags_for(args, "gen-trace")?;
    let bench = args.get("bench").ok_or_else(|| anyhow!("--bench required"))?;
    let n: u64 = args.num("n", 100_000)?;
    let out = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let seed: u64 = args.num("input-seed", reports::REFERENCE_SEED)?;
    let cfg = config_from(args)?;
    let b = find(bench).ok_or_else(|| anyhow!("unknown benchmark {bench}"))?;
    let mut w = TraceWriter::create(Path::new(out))?;
    let t0 = std::time::Instant::now();
    let stats = simulate(&cfg, b.workload(seed).stream(), n, |e| {
        w.write(&TraceRecord::from(e)).expect("trace write");
    });
    let count = w.finish()?;
    println!(
        "wrote {count} records to {out}: cpi={:.3} des_mips={:.3}",
        stats.cpi(),
        count as f64 / t0.elapsed().as_secs_f64() / 1e6
    );
    Ok(())
}

fn cmd_gen_dataset(args: &Args) -> Result<()> {
    check_flags_for(args, "gen-dataset")?;
    let out = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let benches = args
        .list("benches")
        .unwrap_or_else(|| training_set().iter().map(|s| s.to_string()).collect());
    let n_per: u64 = args.num("n-per", 100_000)?;
    let seq: usize = args.num("seq", 32)?;
    let limit: u64 = args.num("limit", 0)?;
    let cfg = config_from(args)?;
    // Dataset generation uses the "test workload" seed 0 (simulation runs
    // use the reference seed), mirroring the paper's input split.
    let mut all = Vec::new();
    for name in &benches {
        let b = find(name).ok_or_else(|| anyhow!("unknown benchmark {name}"))?;
        let (recs, stats) = reports::des_trace(&cfg, &b, n_per, 0);
        println!("  {name}: {} records, cpi={:.3}", recs.len(), stats.cpi());
        all.extend(recs);
    }
    let mode = match args.get("context").unwrap_or("simnet") {
        "ithemal" => simnet::features::ContextMode::Ithemal,
        _ => simnet::features::ContextMode::SimNet,
    };
    // --rob-mix 40,80,120: regenerate the traces under each ROB size and
    // emit one dataset with the ROB size as the config feature (the input
    // the §5 ROB-conditioned model trains against).
    if let Some(mix) = args.list("rob-mix") {
        let mut writer = simnet::trace::DatasetWriter::create(Path::new(out), seq)?;
        let mut seen = std::collections::HashSet::new();
        let mut total_dups = 0u64;
        for rob_s in &mix {
            let rob: usize = rob_s.parse().context("--rob-mix")?;
            let mut rcfg = cfg.clone();
            rcfg.rob_entries = rob;
            let opts = DatasetOptions {
                seq_len: seq,
                dedup: true,
                limit,
                mode,
                cfg_feature: rob as f32 / 256.0,
            };
            for name in &benches {
                let b = find(name).ok_or_else(|| anyhow!("unknown benchmark {name}"))?;
                let (recs, _) = reports::des_trace(&rcfg, &b, n_per / mix.len() as u64, 0);
                total_dups +=
                    simnet::trace::append_dataset(
                        recs.iter(),
                        &rcfg,
                        &opts,
                        &mut writer,
                        &mut seen,
                    )?;
            }
            println!("  rob={rob}: dataset now {} samples", writer.count());
        }
        let written = writer.finish()?;
        println!("dataset {out}: {written} samples ({total_dups} dups removed), rob-mixed");
        return Ok(());
    }
    let opts = DatasetOptions { seq_len: seq, dedup: true, limit, mode, cfg_feature: 0.0 };
    let (written, dups) = build_dataset(all.iter(), &cfg, &opts, Path::new(out))?;
    println!("dataset {out}: {written} samples ({dups} duplicates removed), seq_len={seq}");
    Ok(())
}

fn cmd_simulate_des(args: &Args) -> Result<()> {
    check_flags_for(args, "simulate-des")?;
    let bench = args.get("bench").ok_or_else(|| anyhow!("--bench required"))?;
    let n: u64 = args.num("n", 100_000)?;
    let cfg = config_from(args)?;
    let b = find(bench).ok_or_else(|| anyhow!("unknown benchmark {bench}"))?;
    let seed: u64 = args.num("input-seed", reports::REFERENCE_SEED)?;
    let t0 = std::time::Instant::now();
    let stats = simulate(&cfg, b.workload(seed).stream(), n, |_| {});
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{bench} [{}]: {} instructions, {} cycles, cpi={:.3} ipc={:.3} \
         mispredicts={} l1d_miss={} | {:.3} MIPS",
        cfg.name,
        stats.instructions,
        stats.cycles,
        stats.cpi(),
        stats.ipc(),
        stats.mispredicts,
        stats.l1d_miss,
        stats.instructions as f64 / wall / 1e6
    );
    Ok(())
}

/// Print the human-readable summary of a [`SimReport`] (the `--json` flag
/// additionally writes the machine-readable form).
fn print_report(report: &SimReport) {
    println!(
        "ml[{}] {} instructions: cpi={:.3} (des cpi={:.3}, err={:.2}%) | {:.3} MIPS",
        report.predictor,
        report.outcome.instructions,
        report.cpi(),
        report.des_cpi.unwrap_or(0.0),
        report.cpi_error().unwrap_or(0.0) * 100.0,
        report.mips()
    );
    if report.input.bytes_mapped > 0 || report.input.bytes_copied > 0 {
        println!(
            "input: {} bytes mapped (zero-copy), {} bytes copied",
            report.input.bytes_mapped, report.input.bytes_copied
        );
    }
    if report.input.window_records > 0 {
        println!(
            "streaming: window={} records/sub-trace, peak resident {} records",
            report.input.window_records, report.input.peak_resident_records
        );
    }
    if let Some(stats) = &report.engine {
        let busy = 1.0 - stats.predictor_idle();
        println!(
            "engine: batches={} mean_occupancy={:.1} target_batch={} starved={} filled={} \
             subtraces={} encode_threads={} pipeline_depth={} predictor_busy={:.0}% \
             predictor_idle={:.0}%",
            stats.batches,
            stats.mean_occupancy(),
            stats.target_batch,
            stats.starved,
            stats.filled,
            stats.subtraces,
            stats.encode_threads,
            stats.pipeline_depth,
            busy * 100.0,
            (1.0 - busy) * 100.0
        );
    }
}

/// Engine knobs shared by `simulate-ml` and `submit` (`--target-batch`,
/// `--encode-threads`, `--pipeline-depth`, `--no-fork-predict`).
fn engine_options_from(args: &Args) -> Result<EngineOptions> {
    Ok(EngineOptions {
        target_batch: args.num("target-batch", 0)?,
        encode_threads: args.num("encode-threads", 1)?,
        pipeline_depth: args.num("pipeline-depth", 2)?,
        // Presence flag: forked per-worker predictor handles are the
        // default; --no-fork-predict forces the shared-handle pipeline.
        fork_predict: args.get("no-fork-predict").is_none(),
    })
}

fn cmd_simulate_ml(args: &Args) -> Result<()> {
    check_flags_for(args, "simulate-ml")?;
    let cfg = config_from(args)?;
    let n: u64 = args.num("n", 100_000)?;
    let window: u64 = args.num("window", 0)?;
    let workers: usize = args.num("workers", 1)?;
    let subtraces: usize = args.num("subtraces", 1)?;
    let engine = engine_options_from(args)?;
    if engine.encode_threads > 1 && workers <= 1 && subtraces <= 1 {
        eprintln!(
            "note: --encode-threads/--pipeline-depth only apply to the batch engine; \
             pass --subtraces > 1 or --workers > 1 (running sequentially)"
        );
    }
    let mut sim = Simulation::new()
        .config(&cfg)
        .predictor(predictor_spec_from(args, "c3")?)
        .subtraces(subtraces)
        .workers(workers)
        .window(window)
        .engine(engine)
        .input_seed(args.num("input-seed", reports::REFERENCE_SEED)?)
        // Presence flag: the zero-copy mmap read path is the default;
        // --no-mmap forces the buffered reader for trace files.
        .mmap(args.get("no-mmap").is_none())
        // Windowed streaming decode is the default for mmapped trace
        // files; --streaming false forces the full up-front decode.
        .streaming(args.bool_flag("streaming", true)?);
    sim = if let Some(path) = args.get("trace") {
        // The trace file already fixes the workload; flags that would
        // silently lose to it are rejected, not ignored.
        for f in ["bench", "n", "input-seed"] {
            if args.get(f).is_some() {
                bail!("--trace conflicts with --{f} (the trace file fixes the workload)");
            }
        }
        sim.trace_file(path)
    } else {
        let bench = args.get("bench").ok_or_else(|| anyhow!("--bench or --trace required"))?;
        sim.bench(bench, n)
    };
    let report = sim.run()?;
    print_report(&report);
    if window > 0 {
        print!("{}", simnet::stats::render_cpi_series("windows", &report.outcome.windows));
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Default address shared by `serve` and its client subcommands.
const DEFAULT_ADDR: &str = "127.0.0.1:7878";

fn server_addr(args: &Args) -> String {
    args.get("addr").unwrap_or(DEFAULT_ADDR).to_string()
}

/// Bail with the server's named error (and stable code) unless the
/// response says ok.
fn expect_ok(v: &Value, what: &str) -> Result<()> {
    if v.get("ok").and_then(Value::as_bool) == Some(true) {
        return Ok(());
    }
    let code = v.get("code").and_then(Value::as_str).unwrap_or("error");
    let msg = v.get("error").and_then(Value::as_str).unwrap_or("malformed server response");
    bail!("{what}: {msg} [{code}]")
}

/// Build the [`JobRequest`] a `submit` ships: the same source, config,
/// predictor and engine flags `simulate-ml` takes, plus `--priority`.
fn job_request_from(args: &Args) -> Result<JobRequest> {
    let source = if let Some(path) = args.get("trace") {
        // Same conflict rule as simulate-ml: the trace file fixes the
        // workload, so flags it would shadow are rejected. The path is
        // read by the *server*, so it must be reachable from there.
        for f in ["bench", "n", "input-seed"] {
            if args.get(f).is_some() {
                bail!("--trace conflicts with --{f} (the trace file fixes the workload)");
            }
        }
        JobSource::TraceFile(PathBuf::from(path))
    } else {
        let bench = args.get("bench").ok_or_else(|| anyhow!("--bench or --trace required"))?;
        JobSource::Bench { name: bench.to_string(), n: args.num("n", 100_000)? }
    };
    let mut job = JobRequest::new(source, predictor_spec_from(args, "c3")?);
    job.config = config_spec_from(args)?;
    job.subtraces = args.num("subtraces", 1)?;
    job.workers = args.num("workers", 1)?;
    job.window = args.num("window", 0)?;
    job.input_seed = args.num("input-seed", reports::REFERENCE_SEED)?;
    job.engine = engine_options_from(args)?;
    job.priority = Priority::parse(args.get("priority").unwrap_or("normal"))?;
    job.mmap = args.get("no-mmap").is_none();
    job.streaming = args.bool_flag("streaming", true)?;
    Ok(job)
}

/// Print a completed remote job's report summary and optionally write
/// the embedded [`SimReport`] JSON to a file.
fn finish_remote_report(id: u64, report: &Value, json_out: Option<&str>) -> Result<()> {
    let insns = report.get("instructions").and_then(Value::as_u64).unwrap_or(0);
    let cycles = report.get("cycles").and_then(Value::as_u64).unwrap_or(0);
    let cpi = report.get("cpi").and_then(Value::as_f64).unwrap_or(f64::NAN);
    println!("job {id} done: {insns} instructions, {cycles} cycles, cpi={cpi:.4}");
    if let Some(path) = json_out {
        std::fs::write(path, format!("{}\n", report.render()))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    check_flags_for(args, "serve")?;
    let opts = ServerOptions {
        queue_capacity: args.num("queue-cap", 64usize)?,
        max_cobatch: args.num("max-cobatch", 4usize)?,
        quiet: args.get("quiet").is_some(),
    };
    let server = JobServer::bind(&server_addr(args), opts)?;
    println!("repro job server listening on {}", server.local_addr());
    server.run()
}

fn cmd_submit(args: &Args) -> Result<()> {
    check_flags_for(args, "submit")?;
    let addr = server_addr(args);
    let job = job_request_from(args)?;
    job.validate()?;
    if args.get("follow").is_some() {
        return submit_follow(&addr, &job, args.get("json"));
    }
    if args.get("json").is_some() {
        bail!("--json needs --follow here (or fetch it later with `repro status --id N --json`)");
    }
    let v = protocol::roundtrip(&addr, &protocol::submit_request(&job, false))?;
    expect_ok(&v, "submit")?;
    let id = v
        .get("id")
        .and_then(Value::as_u64)
        .ok_or_else(|| anyhow!("malformed submit response from {addr}"))?;
    println!("job {id} admitted at {addr} (poll with `repro status --addr {addr} --id {id}`)");
    Ok(())
}

/// Streaming submit: keep the connection open and relay the server's
/// event lines until the job completes.
fn submit_follow(addr: &str, job: &JobRequest, json_out: Option<&str>) -> Result<()> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to job server {addr}"))?;
    stream.write_all(protocol::submit_request(job, true).as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        bail!("job server {addr} closed the connection without responding");
    }
    let v = Value::parse(line.trim_end())?;
    expect_ok(&v, "submit")?;
    let id = v
        .get("id")
        .and_then(Value::as_u64)
        .ok_or_else(|| anyhow!("malformed submit response from {addr}"))?;
    println!("job {id} admitted at {addr}");
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("job server {addr} closed the event stream before job {id} finished");
        }
        let ev = Value::parse(line.trim_end())?;
        match ev.get("event").and_then(Value::as_str) {
            Some("state") => {
                println!("job {id}: {}", ev.get("state").and_then(Value::as_str).unwrap_or("?"));
            }
            Some("progress") => {
                let done = ev.get("instructions").and_then(Value::as_u64).unwrap_or(0);
                match ev.get("total").and_then(Value::as_u64) {
                    Some(total) => println!("job {id}: {done}/{total} instructions"),
                    None => println!("job {id}: {done} instructions"),
                }
            }
            Some("done") => {
                let report =
                    ev.get("report").ok_or_else(|| anyhow!("done event without a report"))?;
                return finish_remote_report(id, report, json_out);
            }
            Some("failed") => bail!(
                "job {id} failed: {}",
                ev.get("error").and_then(Value::as_str).unwrap_or("unknown error")
            ),
            _ => bail!("job server {addr} sent an unknown event line: {}", line.trim_end()),
        }
    }
}

fn cmd_status(args: &Args) -> Result<()> {
    check_flags_for(args, "status")?;
    let addr = server_addr(args);
    if args.get("id").is_none() {
        // No --id: server-wide stats.
        for f in ["wait", "json"] {
            if args.get(f).is_some() {
                bail!("--{f} needs --id");
            }
        }
        let v = protocol::roundtrip(&addr, &protocol::stats_request())?;
        expect_ok(&v, "stats")?;
        let jobs = v.get("jobs");
        let count = |k: &str| {
            jobs.and_then(|j| j.get(k)).and_then(Value::as_u64).unwrap_or(0).to_string()
        };
        println!(
            "jobs: queued={} running={} done={} failed={}",
            count("queued"),
            count("running"),
            count("done"),
            count("failed")
        );
        for p in v.get("predictors").and_then(Value::as_arr).unwrap_or(&[]) {
            println!(
                "warm predictor {}: jobs={} served={}",
                p.get("key").and_then(Value::as_str).unwrap_or("?"),
                p.get("jobs").and_then(Value::as_u64).unwrap_or(0),
                p.get("served").and_then(Value::as_u64).unwrap_or(0)
            );
        }
        return Ok(());
    }
    let id: u64 = args.num("id", 0)?;
    let wait = args.get("wait").is_some();
    loop {
        let v = protocol::roundtrip(&addr, &protocol::status_request(id))?;
        expect_ok(&v, "status")?;
        let state = v.get("state").and_then(Value::as_str).unwrap_or("?");
        match state {
            "done" => {
                let report =
                    v.get("report").ok_or_else(|| anyhow!("done status without a report"))?;
                return finish_remote_report(id, report, args.get("json"));
            }
            "failed" => bail!(
                "job {id} failed: {}",
                v.get("error").and_then(Value::as_str).unwrap_or("unknown error")
            ),
            _ => {
                if !wait {
                    let done = v.get("instructions").and_then(Value::as_u64).unwrap_or(0);
                    match v.get("total").and_then(Value::as_u64) {
                        Some(total) => println!("job {id}: {state} ({done}/{total} instructions)"),
                        None => println!("job {id}: {state} ({done} instructions)"),
                    }
                    return Ok(());
                }
                std::thread::sleep(std::time::Duration::from_millis(200));
            }
        }
    }
}

fn cmd_shutdown(args: &Args) -> Result<()> {
    check_flags_for(args, "shutdown")?;
    let addr = server_addr(args);
    let v = protocol::roundtrip(&addr, &protocol::shutdown_request())?;
    expect_ok(&v, "shutdown")?;
    println!("job server at {addr} is shutting down");
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table4");
    match which {
        "table4" | "fig5" | "fig6" | "fig10" | "attribution" | "dataset-size" => {
            check_flags_for(args, &format!("report {which}"))?
        }
        other => {
            bail!("unknown report {other} (table4|fig5|fig6|fig10|attribution|dataset-size)")
        }
    }
    let cfg = config_from(args)?;
    let artifacts = artifacts_dir(args);
    let n: u64 = args.num("n", 50_000)?;
    let benches = args.list("benches");
    let subtrace: usize = args.num("subtrace", 3_000)?;
    match which {
        "table4" => {
            let models = args.list("models").unwrap_or_else(|| {
                vec![
                    "fc3".into(),
                    "c3".into(),
                    "c3_reg".into(),
                    "rb".into(),
                    "lstm2".into(),
                    "ithemal_lstm2".into(),
                ]
            });
            print!("{}", table4::run(&artifacts, &models, &cfg, n, subtrace)?);
        }
        "fig5" => {
            let specs = report_specs(args, &artifacts)?;
            print!("{}", figs::fig5(&cfg, &specs, n, subtrace, benches.as_deref())?);
        }
        "fig6" => {
            let specs = report_specs(args, &artifacts)?;
            let window: u64 = args.num("window", n / 50)?;
            print!("{}", figs::fig6(&cfg, &specs, n, window.max(1), benches.as_deref())?);
        }
        "fig10" => {
            let models = args.list("models").unwrap_or_else(|| vec!["c3".into(), "rb".into()]);
            // Measure sim + des throughput on one benchmark.
            let bench = args.get("bench").unwrap_or("xz");
            let b = find(bench).ok_or_else(|| anyhow!("unknown benchmark {bench}"))?;
            let t0 = std::time::Instant::now();
            let (recs, _) = reports::des_trace(&cfg, &b, n, reports::REFERENCE_SEED);
            let des_mips = n as f64 / t0.elapsed().as_secs_f64() / 1e6;
            let subs = (recs.len() / subtrace.max(1)).max(1);
            // Unloadable models are skipped with the error on stderr
            // (fig10_sim_mips), never silently; simulation failures abort.
            let sim_mips = figs::fig10_sim_mips(&artifacts, &models, &cfg, &recs, subs)?;
            print!("{}", figs::fig10(&artifacts, &models, &cfg, &sim_mips, des_mips)?);
        }
        "attribution" => {
            let spec = predictor_spec_from(args, "c3")?;
            let samples: usize = args.num("samples", 256)?;
            let attr = attribution::attribution(&cfg, &spec, samples, benches.as_deref())?;
            print!("{}", attribution::render(&attr));
        }
        "dataset-size" => {
            // §4.5: 4-benchmark vs 15-benchmark training set (the latter
            // built by `make study`).
            let mut t = simnet::stats::Table::new(&[
                "dataset", "fetch_err", "exec_err", "store_err", "train_seconds",
            ]);
            for (tag, label) in [("c3", "4 benchmarks"), ("c3_big", "15 benchmarks")] {
                match table4::ModelMeta::read(&artifacts, tag) {
                    Some(m) => t.row(vec![
                        label.to_string(),
                        format!("{:.1}%", m.fetch_err * 100.0),
                        format!("{:.1}%", m.exec_err * 100.0),
                        format!("{:.1}%", m.store_err * 100.0),
                        format!("{:.0}s", m.train_seconds),
                    ]),
                    None => println!("({tag}.meta missing — run `make study` for c3_big)"),
                }
            }
            println!("== §4.5: training dataset size ==");
            print!("{}", t.render());
        }
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// Predictor list for fig5/fig6: --models or --table (mixing them is an
/// error, via [`reject_predictor_conflicts`]), on either ML backend
/// (`--backend native` runs every listed model natively).
fn report_specs(args: &Args, artifacts: &Path) -> Result<Vec<PredictorSpec>> {
    reject_predictor_conflicts(args, &["models", "artifacts", "backend"])?;
    if args.get("table").is_some() {
        let seq: usize = args.num("seq", 32)?;
        return Ok(vec![PredictorSpec::table(seq)]);
    }
    let backend = backend_from(args)?;
    let seq: usize = args.num("seq", 32)?;
    let models = args
        .list("models")
        .unwrap_or_else(|| vec!["c3".into(), "rb".into(), "ithemal_lstm2".into()]);
    Ok(models
        .iter()
        .map(|m| match backend {
            Backend::Pjrt => PredictorSpec::ml_tag(artifacts, m, None),
            Backend::Native => PredictorSpec::native(artifacts, m.as_str(), seq),
        })
        .collect())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    const SWEEPS: &[&str] =
        &["subtrace-size", "l2-size", "rob-size", "subtraces", "workers", "branch-predictor"];
    if !SWEEPS.contains(&which) {
        bail!(
            "unknown sweep {which} (subtrace-size|subtraces|workers|branch-predictor|l2-size|rob-size)"
        );
    }
    check_flags_for(args, &format!("sweep {which}"))?;
    let cfg = config_from(args)?;
    let n: u64 = args.num("n", 48_000)?;
    let benches = args.list("benches");
    let spec = predictor_spec_from(args, "c3")?;
    match which {
        "subtrace-size" => {
            let sizes: Vec<usize> =
                args.num_list("sizes")?.unwrap_or_else(|| vec![750, 1_500, 3_000, 6_000, 12_000]);
            print!("{}", sweeps::fig7(&cfg, &spec, n, &sizes, benches.as_deref())?);
        }
        "subtraces" => {
            let counts: Vec<usize> =
                args.num_list("counts")?.unwrap_or_else(|| vec![1, 4, 16, 64, 256, 1024]);
            let bench = args.get("bench").unwrap_or("xz");
            print!("{}", sweeps::fig8(&cfg, &spec, n, &counts, bench)?);
        }
        "workers" => {
            let workers: Vec<usize> = args.num_list("counts")?.unwrap_or_else(|| vec![1, 2, 4, 8]);
            let subtraces: usize = args.num("subtraces", 512)?;
            let bench = args.get("bench").unwrap_or("xz");
            print!("{}", sweeps::fig9(&cfg, &spec, n, &workers, subtraces, bench)?);
        }
        "branch-predictor" => {
            print!("{}", sweeps::table5(&cfg, &spec, n, benches.as_deref())?);
        }
        "l2-size" => {
            let sizes: Vec<u64> =
                args.num_list("sizes")?.unwrap_or_else(|| vec![256, 512, 1024, 2048, 4096]);
            print!("{}", sweeps::l2_sweep(&cfg, &spec, n, &sizes, benches.as_deref())?);
        }
        "rob-size" => {
            let sizes: Vec<usize> = args.num_list("sizes")?.unwrap_or_else(|| vec![40, 80, 120]);
            print!("{}", sweeps::rob_sweep(&cfg, &spec, n, &sizes, benches.as_deref())?);
        }
        _ => unreachable!("validated above"),
    }
    Ok(())
}
