//! Structure-of-arrays encode panels.
//!
//! The batching engine's hot loop used to scatter each slot's 50 features
//! straight into the interleaved (AoS) predictor batch. [`SoaBatch`] splits
//! that work by feature group — one contiguous f32 plane each for the
//! static+history block, the latency block, the dependency flags, and the
//! config feature — so the fill loops are branch-free and vectorizable and
//! the panels can be reused round after round with no per-slot allocation.
//! [`SoaBatch::interleave_into`] then emits the exact slot layout
//! [`ContextTracker::encode_input`] produces, bit for bit, which is what the
//! equivalence suite pins.

use crate::history::HistoryInfo;
use crate::isa::{Inst, MAX_SRC_REGS};

use super::{
    ContextTracker, CFG_FEATURE, DATA_HIST_BASE, DEP_BASE, FETCH_HIST_BASE, LAT_BASE, LAT_SCALE,
    NUM_FEATURES, OP_BASE, REG_BASE,
};

/// Features in the static + history group (`[0, LAT_BASE)`).
pub const STATIC_LEN: usize = LAT_BASE;
/// Features in the latency group (`[LAT_BASE, DEP_BASE)`).
pub const LAT_LEN: usize = DEP_BASE - LAT_BASE;
/// Features in the dependency group (`[DEP_BASE, CFG_FEATURE)`).
pub const DEP_LEN: usize = CFG_FEATURE - DEP_BASE;

/// Branch-free twin of the legacy `encode_static`.
///
/// `REG_NONE` is -1, so `(r + 1) / 64` is exactly the `0.0` the branchy
/// legacy register scatter writes for unused slots — the values (and bits)
/// are identical for every input, which `soa::tests` pins against the
/// legacy encoder.
fn fill_static_row(inst: &Inst, hist: &HistoryInfo, out: &mut [f32]) {
    use crate::isa::OpClass;
    let op = inst.op;
    out[OP_BASE] = op.code() as f32 / 18.0;
    out[OP_BASE + 1] = op.fu_class() as u8 as f32 / 8.0;
    out[OP_BASE + 2] = op.exec_latency() as f32 / 20.0;
    out[OP_BASE + 3] = op.is_load() as u8 as f32;
    out[OP_BASE + 4] = op.is_store() as u8 as f32;
    out[OP_BASE + 5] = op.is_cond_branch() as u8 as f32;
    out[OP_BASE + 6] = matches!(op, OpClass::Jump | OpClass::Call) as u8 as f32;
    out[OP_BASE + 7] = op.is_indirect() as u8 as f32;
    out[OP_BASE + 8] = (op == OpClass::Call) as u8 as f32;
    out[OP_BASE + 9] = (op == OpClass::Ret) as u8 as f32;
    out[OP_BASE + 10] = op.is_barrier() as u8 as f32;
    out[OP_BASE + 11] = op.is_serializing() as u8 as f32;
    out[OP_BASE + 12] = inst.mem_size as f32 / 16.0;
    for (k, &r) in inst.srcs.iter().enumerate() {
        out[REG_BASE + k] = (r as i32 + 1) as f32 / 64.0;
    }
    for (k, &r) in inst.dsts.iter().enumerate() {
        out[REG_BASE + MAX_SRC_REGS + k] = (r as i32 + 1) as f32 / 64.0;
    }
    out[FETCH_HIST_BASE] = hist.mispredict as u8 as f32;
    out[FETCH_HIST_BASE + 1] = hist.fetch_level as f32 / 3.0;
    out[FETCH_HIST_BASE + 2] = hist.fetch_walk[0] as u8 as f32;
    out[FETCH_HIST_BASE + 3] = hist.fetch_walk[1] as u8 as f32;
    out[FETCH_HIST_BASE + 4] = hist.fetch_walk[2] as u8 as f32;
    out[FETCH_HIST_BASE + 5] = hist.fetch_wb[0] as u8 as f32;
    out[FETCH_HIST_BASE + 6] = hist.fetch_wb[1] as u8 as f32;
    out[DATA_HIST_BASE] = hist.data_level as f32 / 3.0;
    out[DATA_HIST_BASE + 1] = hist.data_walk[0] as u8 as f32;
    out[DATA_HIST_BASE + 2] = hist.data_walk[1] as u8 as f32;
    out[DATA_HIST_BASE + 3] = hist.data_walk[2] as u8 as f32;
    out[DATA_HIST_BASE + 4] = hist.data_wb[0] as u8 as f32;
    out[DATA_HIST_BASE + 5] = hist.data_wb[1] as u8 as f32;
    out[DATA_HIST_BASE + 6] = hist.data_wb[2] as u8 as f32;
}

/// Reusable structure-of-arrays encode panels for a batch of slots.
///
/// Geometry is `slots × seq` rows; row `slot * seq + t` holds sequence
/// position `t` of batch slot `slot`. The four planes are allocated once
/// and overwritten in place every round.
pub struct SoaBatch {
    slots: usize,
    seq: usize,
    statics: Vec<f32>,
    lats: Vec<f32>,
    deps: Vec<f32>,
    cfgs: Vec<f32>,
}

impl SoaBatch {
    /// Allocate zeroed panels for `slots` batch slots of `seq` positions.
    pub fn new(slots: usize, seq: usize) -> SoaBatch {
        assert!(seq > 0, "sequence length must be at least 1");
        let rows = slots * seq;
        SoaBatch {
            slots,
            seq,
            statics: vec![0.0; rows * STATIC_LEN],
            lats: vec![0.0; rows * LAT_LEN],
            deps: vec![0.0; rows * DEP_LEN],
            cfgs: vec![0.0; rows],
        }
    }

    /// Batch slots per round.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Sequence positions per slot.
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// The static + history plane (`slots * seq * STATIC_LEN` floats).
    pub fn statics(&self) -> &[f32] {
        &self.statics
    }

    /// The latency plane (`slots * seq * LAT_LEN` floats).
    pub fn lats(&self) -> &[f32] {
        &self.lats
    }

    /// The dependency plane (`slots * seq * DEP_LEN` floats).
    pub fn deps(&self) -> &[f32] {
        &self.deps
    }

    /// The config-feature plane (`slots * seq` floats).
    pub fn cfgs(&self) -> &[f32] {
        &self.cfgs
    }

    /// Encode the model input for `inst` against `tracker`'s context into
    /// the panels of `slot`. Produces exactly the values of
    /// [`ContextTracker::encode_input`], split by feature group.
    pub fn encode_slot(
        &mut self,
        tracker: &ContextTracker,
        inst: &Inst,
        hist: &HistoryInfo,
        slot: usize,
    ) {
        assert!(slot < self.slots, "slot {slot} out of bounds ({} slots)", self.slots);
        let seq = self.seq;
        let base = slot * seq;

        // Row 0: the to-be-predicted instruction (no latency/dep features).
        fill_static_row(inst, hist, &mut self.statics[base * STATIC_LEN..][..STATIC_LEN]);
        self.lats[base * LAT_LEN..][..LAT_LEN].fill(0.0);
        self.deps[base * DEP_LEN..][..DEP_LEN].fill(0.0);
        self.cfgs[base] = tracker.cfg_feature;

        let cur_line = inst.fetch_line();
        let cur_is_mem = inst.op.is_mem() as u8;
        let cur_addr = inst.mem_addr;
        let cur_is_load = inst.is_load() as u8;

        // Rows 1..: context instructions, youngest first. Dependency flags
        // are computed mask-style (0/1 u8 arithmetic, no branches) — same
        // values as the legacy branchy form.
        let mut t = 1;
        for c in tracker.processor_q.iter().rev().chain(tracker.memwrite_q.iter().rev()) {
            if t >= seq {
                break;
            }
            let row = base + t;
            self.statics[row * STATIC_LEN..][..STATIC_LEN].copy_from_slice(&c.feats);
            let l = &mut self.lats[row * LAT_LEN..][..LAT_LEN];
            l[0] = c.residence as f32 / LAT_SCALE;
            l[1] = c.exec_lat as f32 / LAT_SCALE;
            l[2] = c.store_lat as f32 / LAT_SCALE;
            let mem_mask = cur_is_mem & (c.mem_addr != u64::MAX) as u8;
            let same_addr = ((c.mem_addr >> 3) == (cur_addr >> 3)) as u8 & mem_mask;
            let d = &mut self.deps[row * DEP_LEN..][..DEP_LEN];
            d[0] = (c.fetch_line == cur_line) as u8 as f32;
            d[1] = same_addr as f32;
            d[2] = (((c.mem_addr >> 6) == (cur_addr >> 6)) as u8 & mem_mask) as f32;
            d[3] = (((c.mem_addr >> 12) == (cur_addr >> 12)) as u8 & mem_mask) as f32;
            d[4] = (same_addr & c.is_store as u8 & cur_is_load) as f32;
            self.cfgs[row] = tracker.cfg_feature;
            t += 1;
        }

        // Zero the trailing rows — the panels are reused round to round.
        self.statics[(base + t) * STATIC_LEN..(base + seq) * STATIC_LEN].fill(0.0);
        self.lats[(base + t) * LAT_LEN..(base + seq) * LAT_LEN].fill(0.0);
        self.deps[(base + t) * DEP_LEN..(base + seq) * DEP_LEN].fill(0.0);
        self.cfgs[base + t..base + seq].fill(0.0);
    }

    /// Interleave `slot`'s panels into an AoS buffer of
    /// `seq * NUM_FEATURES` floats — the exact layout
    /// [`ContextTracker::encode_input`] writes.
    pub fn interleave_into(&self, slot: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.seq * NUM_FEATURES);
        let seq = self.seq;
        let base = slot * seq;
        for t in 0..seq {
            let row = base + t;
            let o = &mut out[t * NUM_FEATURES..(t + 1) * NUM_FEATURES];
            o[..LAT_BASE].copy_from_slice(&self.statics[row * STATIC_LEN..][..STATIC_LEN]);
            o[LAT_BASE..DEP_BASE].copy_from_slice(&self.lats[row * LAT_LEN..][..LAT_LEN]);
            o[DEP_BASE..CFG_FEATURE].copy_from_slice(&self.deps[row * DEP_LEN..][..DEP_LEN]);
            o[CFG_FEATURE] = self.cfgs[row];
        }
    }

    /// Encode and interleave in one call (the engine's per-slot hot path).
    pub fn encode_into(
        &mut self,
        tracker: &ContextTracker,
        inst: &Inst,
        hist: &HistoryInfo,
        slot: usize,
        out: &mut [f32],
    ) {
        self.encode_slot(tracker, inst, hist, slot);
        self.interleave_into(slot, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{simulate, SimConfig};
    use crate::features::encode_static;
    use crate::trace::TraceRecord;
    use crate::workload::find;

    fn stream(bench: &str, n: u64) -> Vec<TraceRecord> {
        let cfg = SimConfig::default_o3();
        let b = find(bench).unwrap();
        let mut out = Vec::new();
        simulate(&cfg, b.workload(0).stream(), n, |e| out.push(TraceRecord::from(e)));
        out
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn static_row_twin_matches_encode_static() {
        for rec in stream("gcc", 600) {
            let mut legacy = [0.5f32; STATIC_LEN];
            let mut soa = [0.25f32; STATIC_LEN];
            encode_static(&rec.inst, &rec.hist, &mut legacy);
            fill_static_row(&rec.inst, &rec.hist, &mut soa);
            assert_eq!(bits(&legacy), bits(&soa), "pc {:#x}", rec.inst.pc);
        }
    }

    #[test]
    fn soa_matches_legacy_encode_bit_for_bit() {
        let cfg = SimConfig::default_o3();
        for (bench, cfg_feature) in [("gcc", 0.0f32), ("leela", 0.37f32)] {
            let recs = stream(bench, 800);
            let seq = 16;
            let mut tracker = ContextTracker::new(&cfg);
            tracker.cfg_feature = cfg_feature;
            let mut soa = SoaBatch::new(3, seq);
            let mut legacy = vec![0.0f32; seq * NUM_FEATURES];
            let mut via_soa = vec![0.0f32; seq * NUM_FEATURES];
            for (i, rec) in recs.iter().enumerate() {
                tracker.encode_input(&rec.inst, &rec.hist, seq, &mut legacy);
                // Rotate slots so stale panel contents must get overwritten.
                soa.encode_into(&tracker, &rec.inst, &rec.hist, i % 3, &mut via_soa);
                assert_eq!(bits(&legacy), bits(&via_soa), "{bench} inst {i}");
                tracker.push(&rec.inst, &rec.hist, rec.f_lat, rec.e_lat.max(1), rec.s_lat);
            }
        }
    }

    #[test]
    fn trailing_rows_are_cleared_on_reuse() {
        let cfg = SimConfig::default_o3();
        let recs = stream("xz", 300);
        let seq = 8;
        let mut full = ContextTracker::new(&cfg);
        for rec in &recs {
            full.push(&rec.inst, &rec.hist, rec.f_lat, rec.e_lat.max(1), rec.s_lat);
        }
        let mut soa = SoaBatch::new(1, seq);
        let mut out = vec![0.0f32; seq * NUM_FEATURES];
        let rec = &recs[0];
        soa.encode_into(&full, &rec.inst, &rec.hist, 0, &mut out);
        assert!(out[NUM_FEATURES..].iter().any(|&x| x != 0.0), "context rows filled");
        // Re-encode the same slot against an empty tracker: every context
        // row must come back zero despite the dirty panels.
        let empty = ContextTracker::new(&cfg);
        soa.encode_into(&empty, &rec.inst, &rec.hist, 0, &mut out);
        assert!(out[NUM_FEATURES..].iter().all(|&x| x == 0.0), "stale rows leaked");
    }
}
