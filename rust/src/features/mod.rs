//! The 50-feature instruction encoding and context-instruction tracking
//! (paper Table 1 and §3.2 "Context Management").
//!
//! Every instruction is encoded as [`NUM_FEATURES`] = 50 floats. The model
//! input is a sequence of `seq_len` instruction slots: slot 0 is the
//! to-be-predicted instruction, slots 1.. are its *context instructions* —
//! the instructions still inside the processor — youngest first, zero
//! padded. The [`ContextTracker`] maintains the two FIFO queues the paper
//! describes (processor queue ≈ frontend+ROB, memory write queue ≈ SQ) and
//! is shared verbatim between dataset generation (with DES-true latencies)
//! and ML simulation (with predicted latencies), which guarantees
//! train/inference feature consistency.

use std::collections::VecDeque;

use crate::des::config::SimConfig;
use crate::history::HistoryInfo;
use crate::isa::{Inst, MAX_DST_REGS, MAX_SRC_REGS, REG_NONE};

pub mod soa;

/// Features per instruction slot (paper: 50).
pub const NUM_FEATURES: usize = 50;

/// Latency normalization divisor: latencies are fed to the model as
/// `latency / LAT_SCALE` and predicted back the same way.
pub const LAT_SCALE: f32 = 256.0;

// Feature layout within one 50-float slot:
//   [0..13)  operation features
//   [13..27) register indices (8 src + 6 dst)
//   [27..34) fetch-side history (mispredict, level, 3 walk, 2 wb)
//   [34..41) data-side history (level, 3 walk, 3 wb)
//   [41..44) residence / execution / store latency (context only)
//   [44..49) memory-dependency flags vs the current instruction
//   [49]     configuration feature (ROB size for the §5 ROB study)
pub const OP_BASE: usize = 0;
pub const REG_BASE: usize = 13;
pub const FETCH_HIST_BASE: usize = 27;
pub const DATA_HIST_BASE: usize = 34;
pub const LAT_BASE: usize = 41;
pub const DEP_BASE: usize = 44;
pub const CFG_FEATURE: usize = 49;

/// Human-readable names for attribution reports (Figure 11).
pub fn feature_name(i: usize) -> String {
    match i {
        0 => "op_code".into(),
        1 => "fu_class".into(),
        2 => "op_latency_class".into(),
        3 => "is_load".into(),
        4 => "is_store".into(),
        5 => "is_cond_branch".into(),
        6 => "is_uncond_direct".into(),
        7 => "is_indirect".into(),
        8 => "is_call".into(),
        9 => "is_ret".into(),
        10 => "is_membar".into(),
        11 => "is_serializing".into(),
        12 => "mem_size".into(),
        13..=20 => format!("src_reg{}", i - 13),
        21..=26 => format!("dst_reg{}", i - 21),
        27 => "mispredict".into(),
        28 => "fetch_level".into(),
        29..=31 => format!("fetch_walk{}", i - 29),
        32..=33 => format!("fetch_wb{}", i - 32),
        34 => "data_level".into(),
        35..=37 => format!("data_walk{}", i - 35),
        38..=40 => format!("data_wb{}", i - 38),
        41 => "residence_lat".into(),
        42 => "execution_lat".into(),
        43 => "store_lat".into(),
        44 => "dep_same_fetch_line".into(),
        45 => "dep_same_addr".into(),
        46 => "dep_same_line".into(),
        47 => "dep_same_page".into(),
        48 => "dep_raw_store_load".into(),
        49 => "cfg_rob_size".into(),
        _ => format!("feature{i}"),
    }
}

/// Coarse feature groups used by the Figure 11 attribution report.
pub fn feature_group(i: usize) -> &'static str {
    match i {
        0..=12 => "operation",
        13..=26 => "register",
        27..=40 => "memory", // history-context results (cache/TLB/BP)
        41..=43 => "latency",
        44..=48 => "memory",
        _ => "operation",
    }
}

/// Encode the static + history features of `inst` into `out[..41]`.
/// Latency, dependency, and config slots are left untouched.
fn encode_static(inst: &Inst, hist: &HistoryInfo, out: &mut [f32]) {
    use crate::isa::OpClass;
    let op = inst.op;
    out[OP_BASE] = op.code() as f32 / 18.0;
    out[OP_BASE + 1] = op.fu_class() as u8 as f32 / 8.0;
    out[OP_BASE + 2] = op.exec_latency() as f32 / 20.0;
    out[OP_BASE + 3] = op.is_load() as u8 as f32;
    out[OP_BASE + 4] = op.is_store() as u8 as f32;
    out[OP_BASE + 5] = op.is_cond_branch() as u8 as f32;
    out[OP_BASE + 6] = matches!(op, OpClass::Jump | OpClass::Call) as u8 as f32;
    out[OP_BASE + 7] = op.is_indirect() as u8 as f32;
    out[OP_BASE + 8] = (op == OpClass::Call) as u8 as f32;
    out[OP_BASE + 9] = (op == OpClass::Ret) as u8 as f32;
    out[OP_BASE + 10] = op.is_barrier() as u8 as f32;
    out[OP_BASE + 11] = op.is_serializing() as u8 as f32;
    out[OP_BASE + 12] = inst.mem_size as f32 / 16.0;
    for (k, &r) in inst.srcs.iter().enumerate().take(MAX_SRC_REGS) {
        out[REG_BASE + k] = if r == REG_NONE { 0.0 } else { (r + 1) as f32 / 64.0 };
    }
    for (k, &r) in inst.dsts.iter().enumerate().take(MAX_DST_REGS) {
        out[REG_BASE + 8 + k] = if r == REG_NONE { 0.0 } else { (r + 1) as f32 / 64.0 };
    }
    out[FETCH_HIST_BASE] = hist.mispredict as u8 as f32;
    out[FETCH_HIST_BASE + 1] = hist.fetch_level as f32 / 3.0;
    for k in 0..3 {
        out[FETCH_HIST_BASE + 2 + k] = hist.fetch_walk[k] as u8 as f32;
    }
    out[FETCH_HIST_BASE + 5] = hist.fetch_wb[0] as u8 as f32;
    out[FETCH_HIST_BASE + 6] = hist.fetch_wb[1] as u8 as f32;
    out[DATA_HIST_BASE] = hist.data_level as f32 / 3.0;
    for k in 0..3 {
        out[DATA_HIST_BASE + 1 + k] = hist.data_walk[k] as u8 as f32;
    }
    for k in 0..3 {
        out[DATA_HIST_BASE + 4 + k] = hist.data_wb[k] as u8 as f32;
    }
}

/// A context instruction held in the tracker queues.
#[derive(Debug, Clone, Copy)]
struct CtxInst {
    /// Pre-encoded static + history features (first 41 slots).
    feats: [f32; LAT_BASE],
    /// Cycles spent in the processor so far.
    residence: u32,
    /// Predicted/actual execution latency.
    exec_lat: u32,
    /// Predicted/actual store latency (stores only).
    store_lat: u32,
    is_store: bool,
    // identity for dependency flags
    fetch_line: u64,
    mem_addr: u64,
    is_load: bool,
}

/// How context instructions are selected (paper §2.5, "Comparison with
/// Ithemal").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ContextMode {
    /// SimNet: only instructions still inside the processor (selected by
    /// the clock/retirement model), with their latency features.
    #[default]
    SimNet,
    /// Ithemal-style: a fixed window of the most recent instructions,
    /// retired or not, with latency features zeroed. (We keep the SimNet
    /// history/dependency features — the paper's "enhanced" Ithemal.)
    Ithemal,
}

/// The paper's two context FIFOs plus the clock bookkeeping of §3.2.
///
/// Thread-safety contract: [`ContextTracker::encode_input`] takes `&self`
/// and the tracker owns all of its state, so the pipelined `BatchEngine`
/// encodes from multiple worker threads against *disjoint* trackers
/// (each sub-trace's tracker is owned by exactly one encode worker).
pub struct ContextTracker {
    processor_q: VecDeque<CtxInst>,
    memwrite_q: VecDeque<CtxInst>,
    /// Maximum instructions the processor can hold (bounds processor_q).
    proc_capacity: usize,
    sq_capacity: usize,
    retire_width: u32,
    mode: ContextMode,
    /// Current simulated time (paper's `curTick`).
    pub cur_tick: u64,
    /// Extra config feature value broadcast into every slot (ROB study).
    pub cfg_feature: f32,
}

impl ContextTracker {
    pub fn new(cfg: &SimConfig) -> Self {
        Self::with_mode(cfg, ContextMode::SimNet)
    }

    pub fn with_mode(cfg: &SimConfig, mode: ContextMode) -> Self {
        ContextTracker {
            processor_q: VecDeque::with_capacity(cfg.max_context()),
            memwrite_q: VecDeque::with_capacity(cfg.sq_entries),
            proc_capacity: match mode {
                ContextMode::SimNet => {
                    cfg.rob_entries + (cfg.fetch_width * cfg.frontend_depth * 2) as usize
                }
                // Fixed window: large enough for any export seq_len.
                ContextMode::Ithemal => 256,
            },
            sq_capacity: cfg.sq_entries,
            retire_width: cfg.commit_width,
            mode,
            cur_tick: 0,
            cfg_feature: 0.0,
        }
    }

    /// Number of live context instructions.
    pub fn len(&self) -> usize {
        self.processor_q.len() + self.memwrite_q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Encode the model input for `inst` into `out` (length
    /// `seq_len * NUM_FEATURES`, slot 0 = current instruction, slots 1.. =
    /// context youngest-first). The buffer may be reused across calls —
    /// every slot is fully written or explicitly cleared.
    pub fn encode_input(&self, inst: &Inst, hist: &HistoryInfo, seq_len: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), seq_len * NUM_FEATURES);
        // Slot 0: the to-be-predicted instruction.
        out[..NUM_FEATURES].fill(0.0);
        encode_static(inst, hist, &mut out[..LAT_BASE]);
        out[CFG_FEATURE] = self.cfg_feature;

        let cur_line = inst.fetch_line();
        let cur_is_mem = inst.op.is_mem();
        let cur_addr = inst.mem_addr;
        let cur_is_load = inst.is_load();

        // Slots 1..: context instructions, youngest first: processor queue
        // back-to-front, then memory write queue back-to-front.
        let mut slot = 1;
        for c in self.processor_q.iter().rev().chain(self.memwrite_q.iter().rev()) {
            if slot >= seq_len {
                break;
            }
            let o = &mut out[slot * NUM_FEATURES..(slot + 1) * NUM_FEATURES];
            o[..LAT_BASE].copy_from_slice(&c.feats);
            o[LAT_BASE] = c.residence as f32 / LAT_SCALE;
            o[LAT_BASE + 1] = c.exec_lat as f32 / LAT_SCALE;
            o[LAT_BASE + 2] = c.store_lat as f32 / LAT_SCALE;
            o[DEP_BASE] = (c.fetch_line == cur_line) as u8 as f32;
            if cur_is_mem && c.mem_addr != u64::MAX {
                let same_addr = (c.mem_addr >> 3) == (cur_addr >> 3);
                o[DEP_BASE + 1] = same_addr as u8 as f32;
                o[DEP_BASE + 2] = ((c.mem_addr >> 6) == (cur_addr >> 6)) as u8 as f32;
                o[DEP_BASE + 3] = ((c.mem_addr >> 12) == (cur_addr >> 12)) as u8 as f32;
                o[DEP_BASE + 4] = (same_addr && c.is_store && cur_is_load) as u8 as f32;
            } else {
                o[DEP_BASE + 1] = 0.0;
                o[DEP_BASE + 2] = 0.0;
                o[DEP_BASE + 3] = 0.0;
                o[DEP_BASE + 4] = 0.0;
            }
            o[CFG_FEATURE] = self.cfg_feature;
            slot += 1;
        }
        // Clear remaining slots (the buffer may be reused between calls).
        out[slot * NUM_FEATURES..].fill(0.0);
    }

    /// Insert `inst` with its (predicted or ground-truth) latencies and
    /// advance the clock by its fetch latency, retiring whatever completes
    /// (paper §3.2 "Clock Management").
    pub fn push(&mut self, inst: &Inst, hist: &HistoryInfo, f: u32, e: u32, s: u32) {
        if self.mode == ContextMode::Ithemal {
            // Fixed recency window: no clock, no retirement, no latency
            // features — the instruction stream order is the only signal.
            self.cur_tick += f as u64;
            let mut feats = [0.0f32; LAT_BASE];
            encode_static(inst, hist, &mut feats);
            self.processor_q.push_back(CtxInst {
                feats,
                residence: 0,
                exec_lat: 0,
                store_lat: 0,
                is_store: inst.is_store(),
                fetch_line: inst.fetch_line(),
                mem_addr: if inst.op.is_mem() { inst.mem_addr } else { u64::MAX },
                is_load: inst.is_load(),
            });
            if self.processor_q.len() > self.proc_capacity {
                self.processor_q.pop_front();
            }
            return;
        }
        // Advance time: residence of everything in flight grows by F.
        if f > 0 {
            self.cur_tick += f as u64;
            for c in self.processor_q.iter_mut() {
                c.residence = c.residence.saturating_add(f);
            }
            for c in self.memwrite_q.iter_mut() {
                c.residence = c.residence.saturating_add(f);
            }
        }
        self.retire(f);

        let mut feats = [0.0f32; LAT_BASE];
        encode_static(inst, hist, &mut feats);
        let is_store = inst.is_store();
        self.processor_q.push_back(CtxInst {
            feats,
            residence: 0,
            exec_lat: e,
            store_lat: s,
            is_store,
            fetch_line: inst.fetch_line(),
            mem_addr: if inst.op.is_mem() { inst.mem_addr } else { u64::MAX },
            is_load: inst.is_load(),
        });
        // Hard capacity: the oldest instruction must leave once the
        // processor is full (mirrors finite ROB+frontend).
        while self.processor_q.len() > self.proc_capacity {
            self.force_retire_head();
        }
    }

    /// Retire completed instructions: in order from the processor queue
    /// head (bounded by retire bandwidth × elapsed cycles), and any number
    /// from the memory write queue.
    fn retire(&mut self, elapsed: u32) {
        let max_retire = (self.retire_width as u64 * elapsed.max(1) as u64) as usize;
        let mut retired = 0;
        while retired < max_retire {
            match self.processor_q.front() {
                Some(head) if head.residence >= head.exec_lat => {
                    self.force_retire_head();
                    retired += 1;
                }
                _ => break,
            }
        }
        // Memory write queue retires freely from its tail.
        self.memwrite_q.retain(|c| c.residence < c.store_lat);
    }

    fn force_retire_head(&mut self) {
        if let Some(head) = self.processor_q.pop_front() {
            if head.is_store && head.residence < head.store_lat {
                if self.memwrite_q.len() == self.sq_capacity {
                    self.memwrite_q.pop_front();
                }
                self.memwrite_q.push_back(head);
            }
        }
    }

    /// Drain: advance time until everything has left the machine; returns
    /// the drain cycles (the paper's `Delta` in Eq. 1).
    pub fn drain(&mut self) -> u64 {
        let mut delta = 0u64;
        while !self.is_empty() {
            let step = self
                .processor_q
                .front()
                .map(|h| h.exec_lat.saturating_sub(h.residence).max(1))
                .unwrap_or_else(|| {
                    self.memwrite_q
                        .iter()
                        .map(|c| c.store_lat.saturating_sub(c.residence).max(1))
                        .min()
                        .unwrap_or(1)
                });
            for c in self.processor_q.iter_mut() {
                c.residence = c.residence.saturating_add(step);
            }
            for c in self.memwrite_q.iter_mut() {
                c.residence = c.residence.saturating_add(step);
            }
            delta += step as u64;
            self.retire(step);
        }
        self.cur_tick += delta;
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    fn cfg() -> SimConfig {
        SimConfig::default_o3()
    }

    fn inst(pc: u64) -> Inst {
        Inst { pc, op: OpClass::IntAlu, ..Default::default() }
    }

    fn hist() -> HistoryInfo {
        HistoryInfo { fetch_level: 1, ..Default::default() }
    }

    #[test]
    fn context_tracker_is_send_and_sync() {
        // The pipelined BatchEngine moves trackers into encode workers and
        // calls `encode_input` (&self) from them; this must stay true.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ContextTracker>();
    }

    #[test]
    fn encode_shape_and_slot0() {
        let t = ContextTracker::new(&cfg());
        let mut buf = vec![0.0f32; 64 * NUM_FEATURES];
        let i = inst(0x1000);
        t.encode_input(&i, &hist(), 64, &mut buf);
        // Slot 0 carries op features; latency slots are zero.
        assert!(buf[OP_BASE + 2] > 0.0);
        assert_eq!(buf[LAT_BASE], 0.0);
        // No context yet: slot 1 is all zero.
        assert!(buf[NUM_FEATURES..2 * NUM_FEATURES].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn context_appears_youngest_first() {
        let mut t = ContextTracker::new(&cfg());
        let mut a = inst(0x1000);
        a.op = OpClass::IntMult;
        t.push(&a, &hist(), 1, 100, 0);
        let mut b = inst(0x2000);
        b.op = OpClass::FloatDiv;
        t.push(&b, &hist(), 1, 100, 0);
        let mut buf = vec![0.0f32; 8 * NUM_FEATURES];
        t.encode_input(&inst(0x3000), &hist(), 8, &mut buf);
        // Slot 1 = youngest = b (FloatDiv), slot 2 = a (IntMult).
        let code1 = buf[NUM_FEATURES + OP_BASE];
        let code2 = buf[2 * NUM_FEATURES + OP_BASE];
        assert!((code1 - OpClass::FloatDiv.code() as f32 / 18.0).abs() < 1e-6);
        assert!((code2 - OpClass::IntMult.code() as f32 / 18.0).abs() < 1e-6);
    }

    #[test]
    fn residence_advances_and_retires() {
        let mut t = ContextTracker::new(&cfg());
        t.push(&inst(0x1000), &hist(), 0, 5, 0);
        assert_eq!(t.len(), 1);
        // Fetch the next instruction 10 cycles later: first retires.
        t.push(&inst(0x1004), &hist(), 10, 5, 0);
        assert_eq!(t.len(), 1, "completed instruction should have retired");
    }

    #[test]
    fn in_order_retirement_blocks_younger() {
        let mut t = ContextTracker::new(&cfg());
        // Head is slow (exec 100), next is fast (exec 1).
        t.push(&inst(0x1000), &hist(), 0, 100, 0);
        t.push(&inst(0x1004), &hist(), 1, 1, 0);
        t.push(&inst(0x1008), &hist(), 10, 1, 0);
        // The fast one behind the slow head must still be present.
        assert_eq!(t.len(), 3, "younger retired before older head");
    }

    #[test]
    fn stores_move_to_memwrite_queue() {
        let mut t = ContextTracker::new(&cfg());
        let mut st = inst(0x1000);
        st.op = OpClass::Store;
        st.mem_addr = 0x5000;
        st.mem_size = 8;
        t.push(&st, &hist(), 0, 2, 50);
        t.push(&inst(0x1004), &hist(), 5, 1, 0); // advance 5: store retires from proc q
        assert_eq!(t.len(), 2, "store should be in memwrite queue + new inst");
        t.push(&inst(0x1008), &hist(), 60, 1, 0); // advance past store latency
        assert_eq!(t.len(), 1, "store should have left the memwrite queue");
    }

    #[test]
    fn dependency_flags_set() {
        let mut t = ContextTracker::new(&cfg());
        let mut st = inst(0x1000);
        st.op = OpClass::Store;
        st.mem_addr = 0x8000;
        st.mem_size = 8;
        t.push(&st, &hist(), 0, 100, 120);
        let mut ld = inst(0x1004);
        ld.op = OpClass::Load;
        ld.mem_addr = 0x8000;
        ld.mem_size = 8;
        let mut buf = vec![0.0f32; 4 * NUM_FEATURES];
        t.encode_input(&ld, &hist(), 4, &mut buf);
        let slot1 = &buf[NUM_FEATURES..2 * NUM_FEATURES];
        assert_eq!(slot1[DEP_BASE], 1.0, "same fetch line");
        assert_eq!(slot1[DEP_BASE + 1], 1.0, "same addr");
        assert_eq!(slot1[DEP_BASE + 2], 1.0, "same line");
        assert_eq!(slot1[DEP_BASE + 3], 1.0, "same page");
        assert_eq!(slot1[DEP_BASE + 4], 1.0, "raw store->load");
    }

    #[test]
    fn capacity_bounded() {
        let c = cfg();
        let mut t = ContextTracker::new(&c);
        for k in 0..500 {
            t.push(&inst(0x1000 + 4 * k), &hist(), 0, 10_000, 0);
        }
        assert!(t.len() <= c.max_context() + c.sq_entries);
    }

    #[test]
    fn drain_empties_everything() {
        let mut t = ContextTracker::new(&cfg());
        for k in 0..20 {
            let mut i = inst(0x1000 + 4 * k);
            if k % 3 == 0 {
                i.op = OpClass::Store;
                i.mem_addr = 0x9000 + 8 * k;
                i.mem_size = 8;
            }
            t.push(&i, &hist(), 1, 20 + k as u32, 40 + k as u32);
        }
        let delta = t.drain();
        assert!(t.is_empty());
        assert!(delta > 0);
    }

    #[test]
    fn truncation_keeps_youngest() {
        let c = cfg();
        let mut t = ContextTracker::new(&c);
        for k in 0..80 {
            let mut i = inst(0x1000 + 4 * k);
            i.op = if k == 79 { OpClass::FloatSqrt } else { OpClass::IntAlu };
            t.push(&i, &hist(), 0, 10_000, 0);
        }
        let mut buf = vec![0.0f32; 8 * NUM_FEATURES];
        t.encode_input(&inst(0x5000), &hist(), 8, &mut buf);
        // Slot 1 must be the youngest pushed (FloatSqrt), even though the
        // queue holds more instructions than fit in 8 slots.
        let code1 = buf[NUM_FEATURES + OP_BASE];
        assert!((code1 - OpClass::FloatSqrt.code() as f32 / 18.0).abs() < 1e-6);
    }
}
