//! `.smw` — the weight-tensor container shared between the python training
//! side (which writes it) and the rust runtime (which reads it and feeds
//! the tensors to the AOT-compiled model as runtime arguments).
//!
//! Format (little-endian):
//! ```text
//! magic "SMW1"
//! u32   tensor count
//! per tensor:
//!   u16  name length, name bytes (utf-8)
//!   u32  ndim, u32 dims[ndim]
//!   f32  data[prod(dims)]
//! ```
//! Keeping weights *outside* the HLO (as executable arguments rather than
//! baked constants) means retraining — e.g. for the §5 ROB study — needs
//! no re-export or re-compile of the model artifact.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SMW1";

/// A named f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(name: impl Into<String>, dims: Vec<usize>, data: Vec<f32>) -> Self {
        let t = Tensor { name: name.into(), dims, data };
        assert_eq!(t.len(), t.data.len(), "tensor {} dims/data mismatch", t.name);
        t
    }

    /// Element count implied by dims.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An ordered collection of named tensors (order = python export order =
/// the argument order of the AOT executable after the input batch).
#[derive(Debug, Clone, Default)]
pub struct TensorFile {
    pub tensors: Vec<Tensor>,
}

impl TensorFile {
    /// Look up a tensor by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Group tensor names -> dims, for diagnostics.
    pub fn summary(&self) -> BTreeMap<String, Vec<usize>> {
        self.tensors.iter().map(|t| (t.name.clone(), t.dims.clone())).collect()
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn write(&self, path: &Path) -> io::Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            let name = t.name.as_bytes();
            w.write_all(&(name.len() as u16).to_le_bytes())?;
            w.write_all(name)?;
            w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for &d in &t.dims {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &v in &t.data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        w.flush()
    }

    pub fn read(path: &Path) -> io::Result<Self> {
        let mut r = BufReader::new(File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "not an .smw file"));
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let count = u32::from_le_bytes(b4);
        let mut tensors = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let mut b2 = [0u8; 2];
            r.read_exact(&mut b2)?;
            let name_len = u16::from_le_bytes(b2) as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
            r.read_exact(&mut b4)?;
            let ndim = u32::from_le_bytes(b4) as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                r.read_exact(&mut b4)?;
                dims.push(u32::from_le_bytes(b4) as usize);
            }
            let n: usize = dims.iter().product();
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push(Tensor { name, dims, data });
        }
        Ok(TensorFile { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("simnet_tensor_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let tf = TensorFile {
            tensors: vec![
                Tensor::new("conv0/w", vec![2, 50, 64], (0..6400).map(|i| i as f32).collect()),
                Tensor::new("conv0/b", vec![64], vec![0.5; 64]),
                Tensor::new("fc/w", vec![8, 3], (0..24).map(|i| -(i as f32)).collect()),
            ],
        };
        let path = tmp("rt.smw");
        tf.write(&path).unwrap();
        let back = TensorFile::read(&path).unwrap();
        assert_eq!(back.tensors, tf.tensors);
        assert_eq!(back.param_count(), 6400 + 64 + 24);
        assert_eq!(back.get("conv0/b").unwrap().dims, vec![64]);
        assert!(back.get("nope").is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.smw");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorFile::read(&path).is_err());
    }

    #[test]
    #[should_panic]
    fn dims_data_mismatch_panics() {
        Tensor::new("x", vec![2, 2], vec![1.0; 3]);
    }
}
