//! Parameter sweeps: Figures 7/8/9 (parallel accuracy & throughput,
//! worker scaling, power efficiency) and the §5 case studies (Table 5
//! branch predictors, L2-size exploration, ROB-size exploration).

use anyhow::Result;

use crate::api::{PredictorSpec, Simulation};
use crate::des::{BpChoice, SimConfig};
use crate::stats::{cpi_error, mean, speedup_pct, Table};

use super::{des_trace, pick_benches, ACCEL_TDP_WATTS, CPU_TDP_WATTS, REFERENCE_SEED};

/// Figure 7: parallel-simulation error vs sub-trace size.
pub fn fig7(
    cfg: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    sizes: &[usize],
    benches: Option<&[String]>,
) -> Result<String> {
    let mut report = String::from("== Figure 7: parallel error vs sub-trace size ==\n");
    let mut table = Table::new(&["subtrace_size", "avg_err_vs_des", "avg_err_vs_sequential"]);
    let mut predictor = spec.build()?;
    let selected = pick_benches(benches);
    // Reference: sequential simulation per benchmark.
    let mut refs = Vec::new();
    for b in &selected {
        let (recs, des) = des_trace(cfg, b, n, REFERENCE_SEED);
        let seq_out = Simulation::new()
            .records(&recs)
            .config(cfg)
            .predictor_ref(predictor.as_mut())
            .run()?;
        let seq_cpi = seq_out.cpi();
        refs.push((recs, des.cpi(), seq_cpi));
    }
    for &size in sizes {
        let mut errs_des = Vec::new();
        let mut errs_seq = Vec::new();
        for (recs, des_cpi, seq_cpi) in &refs {
            let subs = (recs.len() / size).max(1);
            let out = Simulation::new()
                .records(recs)
                .config(cfg)
                .predictor_ref(predictor.as_mut())
                .subtraces(subs)
                .run()?;
            errs_des.push(cpi_error(out.cpi(), *des_cpi));
            errs_seq.push(cpi_error(out.cpi(), *seq_cpi));
        }
        table.row(vec![
            size.to_string(),
            format!("{:.2}%", mean(&errs_des) * 100.0),
            format!("{:.2}%", mean(&errs_seq) * 100.0),
        ]);
    }
    report.push_str(&table.render());
    Ok(report)
}

/// Figure 8: simulation throughput vs number of sub-traces.
pub fn fig8(
    cfg: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    counts: &[usize],
    bench: &str,
) -> Result<String> {
    let mut report = String::from("== Figure 8: throughput vs #sub-traces ==\n");
    let mut table = Table::new(&["subtraces", "MIPS", "speedup_vs_1"]);
    let b = pick_benches(Some(&[bench.to_string()]))
        .pop()
        .ok_or_else(|| anyhow::anyhow!("unknown bench {bench}"))?;
    let (recs, _) = des_trace(cfg, &b, n, REFERENCE_SEED);
    let mut predictor = spec.build()?;
    let mut base = 0.0;
    for &s in counts {
        let out = Simulation::new()
            .records(&recs)
            .config(cfg)
            .predictor_ref(predictor.as_mut())
            .subtraces(s)
            .run()?;
        let mips = out.mips();
        if s == counts[0] {
            base = mips;
        }
        table.row(vec![
            s.to_string(),
            format!("{mips:.3}"),
            format!("{:.1}x", mips / base.max(1e-12)),
        ]);
    }
    report.push_str(&table.render());
    Ok(report)
}

/// Figure 9 + §4.2 power efficiency: concurrent-job scaling over the
/// shared batching engine, against the DES line. Since the engine
/// refactor all jobs share ONE predictor (one accelerator), so the
/// quantity that scales with job count is predictor-batch occupancy —
/// the paper's device-scaling argument recast for a single shared
/// device; the power model books one CPU socket plus one accelerator.
pub fn fig9(
    cfg: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    workers: &[usize],
    subtraces: usize,
    bench: &str,
) -> Result<String> {
    let mut report = String::from("== Figure 9: concurrent-job scaling (shared engine) ==\n");
    let b = pick_benches(Some(&[bench.to_string()]))
        .pop()
        .ok_or_else(|| anyhow::anyhow!("unknown bench {bench}"))?;
    let t_des = std::time::Instant::now();
    let (recs, _) = des_trace(cfg, &b, n, REFERENCE_SEED);
    let des_wall = t_des.elapsed().as_secs_f64();
    let des_mips = n as f64 / des_wall / 1e6;
    let mut predictor = spec.build()?;
    let mut table = Table::new(&[
        "jobs", "MIPS", "speedup_vs_des", "batch_occupancy", "KIPS/W(sim)", "KIPS/W(des)",
    ]);
    for &w in workers {
        let run = Simulation::new()
            .records(&recs)
            .config(cfg)
            .predictor_ref(predictor.as_mut())
            .workers(w)
            .subtraces(subtraces.max(w))
            .run()?;
        let stats = run.engine.clone().unwrap_or_default();
        let mips = run.mips();
        // Power model: DES burns one CPU socket; the ML simulator burns
        // a CPU socket plus the one shared accelerator.
        let sim_watts = CPU_TDP_WATTS + ACCEL_TDP_WATTS;
        table.row(vec![
            w.to_string(),
            format!("{mips:.3}"),
            format!("{:.1}x", mips / des_mips.max(1e-12)),
            format!("{:.1}", stats.mean_occupancy()),
            format!("{:.2}", mips * 1e3 / sim_watts),
            format!("{:.2}", des_mips * 1e3 / CPU_TDP_WATTS),
        ]);
    }
    report.push_str(&table.render());
    report.push_str(&format!("des reference: {des_mips:.3} MIPS\n"));
    Ok(report)
}

/// Sub-trace size used by the case-study sweeps: large enough that the
/// boundary error is negligible (Figure 7) while keeping inference batched.
const SWEEP_SUBTRACE: usize = 3_000;

fn par_subs(len: usize) -> usize {
    (len / SWEEP_SUBTRACE).max(1)
}

/// Table 5: branch-predictor study. For each predictor, re-run the DES
/// (whose history sim embeds that predictor) and the ML simulator on the
/// resulting traces; report average speedups vs the bi-mode baseline and
/// the per-benchmark relative-error range.
pub fn table5(
    cfg_base: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    benches: Option<&[String]>,
) -> Result<String> {
    let mut report = String::from("== Table 5: branch predictor study ==\n");
    let mut table = Table::new(&[
        "predictor", "des_speedup", "sim_speedup", "rel_err_min", "rel_err_max",
    ]);
    let mut predictor = spec.build()?;
    let selected = pick_benches(benches);

    // Baseline: bi-mode.
    let mut base_des = Vec::new();
    let mut base_sim = Vec::new();
    for b in &selected {
        let (recs, des) = des_trace(cfg_base, b, n, REFERENCE_SEED);
        let out = Simulation::new()
            .records(&recs)
            .config(cfg_base)
            .predictor_ref(predictor.as_mut())
            .subtraces(par_subs(recs.len()))
            .run()?;
        base_des.push(des.cycles);
        base_sim.push(out.outcome.cycles);
    }

    for (name, bp) in [("BiMode_l", BpChoice::BiModeLarge), ("TAGE-lite", BpChoice::TageLite)] {
        let mut cfg = cfg_base.clone();
        cfg.bp = bp;
        let mut des_spd = Vec::new();
        let mut sim_spd = Vec::new();
        let mut rel_err = Vec::new();
        for (k, b) in selected.iter().enumerate() {
            let (recs, des) = des_trace(&cfg, b, n, REFERENCE_SEED);
            let out = Simulation::new()
                .records(&recs)
                .config(&cfg)
                .predictor_ref(predictor.as_mut())
                .subtraces(par_subs(recs.len()))
                .run()?;
            let d = speedup_pct(base_des[k], des.cycles);
            let s = speedup_pct(base_sim[k], out.outcome.cycles);
            des_spd.push(d);
            sim_spd.push(s);
            rel_err.push(s - d);
        }
        let lo = rel_err.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rel_err.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        table.row(vec![
            name.to_string(),
            format!("{:.1}%", mean(&des_spd)),
            format!("{:.1}%", mean(&sim_spd)),
            format!("{lo:+.1}%"),
            format!("{hi:+.1}%"),
        ]);
    }
    report.push_str(&table.render());
    Ok(report)
}

/// §5 L2-size exploration: speedups under L2 sizes vs the smallest, DES vs
/// ML sim; prints the average absolute speedup error.
pub fn l2_sweep(
    cfg_base: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    sizes_kb: &[u64],
    benches: Option<&[String]>,
) -> Result<String> {
    let mut report = String::from("== L2 cache size exploration (§5) ==\n");
    let mut table = Table::new(&["l2_size", "des_speedup", "sim_speedup", "abs_err"]);
    let mut predictor = spec.build()?;
    let selected = pick_benches(benches);
    let mut per_size: Vec<(u64, Vec<u64>, Vec<u64>)> = Vec::new();
    for &kb in sizes_kb {
        let mut cfg = cfg_base.clone();
        cfg.l2.size = kb << 10;
        let mut des_c = Vec::new();
        let mut sim_c = Vec::new();
        for b in &selected {
            let (recs, des) = des_trace(&cfg, b, n, REFERENCE_SEED);
            let out = Simulation::new()
                .records(&recs)
                .config(&cfg)
                .predictor_ref(predictor.as_mut())
                .subtraces(par_subs(recs.len()))
                .run()?;
            des_c.push(des.cycles);
            sim_c.push(out.outcome.cycles);
        }
        per_size.push((kb, des_c, sim_c));
    }
    let (base_kb, base_des, base_sim) = per_size[0].clone();
    let mut errs = Vec::new();
    for (kb, des_c, sim_c) in &per_size {
        let des_spd: Vec<f64> =
            des_c.iter().zip(&base_des).map(|(n2, b)| speedup_pct(*b, *n2)).collect();
        let sim_spd: Vec<f64> =
            sim_c.iter().zip(&base_sim).map(|(n2, b)| speedup_pct(*b, *n2)).collect();
        let err = (mean(&sim_spd) - mean(&des_spd)).abs();
        if *kb != base_kb {
            errs.push(err);
        }
        table.row(vec![
            format!("{}KB", kb),
            format!("{:.1}%", mean(&des_spd)),
            format!("{:.1}%", mean(&sim_spd)),
            format!("{err:.1}%"),
        ]);
    }
    report.push_str(&table.render());
    report.push_str(&format!("avg speedup error vs des: {:.2}%\n", mean(&errs)));
    Ok(report)
}

/// §5 ROB-size exploration: the predictor sees the ROB size as the config
/// feature (features::CFG_FEATURE); requires a model trained with that
/// feature varied (tag `c3_rob`), else falls back to the given predictor.
pub fn rob_sweep(
    cfg_base: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    rob_sizes: &[usize],
    benches: Option<&[String]>,
) -> Result<String> {
    let mut report = String::from("== ROB size exploration (§5) ==\n");
    // The paper conditions the model on the ROB size via an input feature;
    // that only works for a model *trained* with the feature varied
    // (`make study` -> c3_rob). With an unconditioned model the feature is
    // held at 0 and the report documents that the simulator cannot see the
    // config change (the paper's motivation for the conditioned model).
    let conditioned = spec.label().contains("rob");
    if !conditioned {
        report.push_str(
            "(model is not ROB-conditioned; cfg feature disabled - run `make study` and pass --model c3_rob)\n",
        );
    }
    let mut table = Table::new(&["rob", "des_speedup", "sim_speedup"]);
    let mut predictor = spec.build()?;
    let selected = pick_benches(benches);
    let mut rows: Vec<(usize, u64, u64)> = Vec::new();
    for &rob in rob_sizes {
        let mut cfg = cfg_base.clone();
        cfg.rob_entries = rob;
        cfg.iq_entries = (rob * 4 / 5).max(cfg_base.iq_entries);
        let mut des_sum = 0u64;
        let mut sim_sum = 0u64;
        for b in &selected {
            let (recs, des) = des_trace(&cfg, b, n, REFERENCE_SEED);
            // ML simulation with the ROB size as the config input feature.
            let out = Simulation::new()
                .records(&recs)
                .config(&cfg)
                .predictor_ref(predictor.as_mut())
                .subtraces(par_subs(recs.len()))
                .cfg_feature(if conditioned { rob as f32 / 256.0 } else { 0.0 })
                .run()?;
            des_sum += des.cycles;
            sim_sum += out.outcome.cycles;
        }
        rows.push((rob, des_sum, sim_sum));
    }
    let (_, base_des, base_sim) = rows[0];
    for (rob, des_c, sim_c) in &rows {
        table.row(vec![
            rob.to_string(),
            format!("{:.1}%", speedup_pct(base_des, *des_c)),
            format!("{:.1}%", speedup_pct(base_sim, *sim_c)),
        ]);
    }
    report.push_str(&table.render());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (SimConfig, PredictorSpec, Vec<String>) {
        (
            SimConfig::default_o3(),
            PredictorSpec::table(16),
            vec!["exchange2".to_string(), "lbm".to_string()],
        )
    }

    #[test]
    fn fig7_table_shape() {
        let (cfg, choice, names) = tiny();
        let out = fig7(&cfg, &choice, 2_000, &[250, 1000], Some(&names)).unwrap();
        assert!(out.contains("250") && out.contains("1000"));
    }

    #[test]
    fn fig8_reports_speedup() {
        let (cfg, choice, _) = tiny();
        let out = fig8(&cfg, &choice, 2_000, &[1, 8], "leela").unwrap();
        assert!(out.contains("speedup_vs_1"));
    }

    #[test]
    fn table5_runs() {
        let (cfg, choice, names) = tiny();
        let out = table5(&cfg, &choice, 2_000, Some(&names)).unwrap();
        assert!(out.contains("BiMode_l") && out.contains("TAGE-lite"));
    }

    #[test]
    fn l2_sweep_monotone_des() {
        let (cfg, choice, _) = tiny();
        let names = vec!["mcf".to_string()];
        let out = l2_sweep(&cfg, &choice, 4_000, &[256, 4096], Some(&names)).unwrap();
        assert!(out.contains("256KB") && out.contains("4096KB"));
    }

    #[test]
    fn rob_sweep_runs() {
        let (cfg, choice, names) = tiny();
        let out = rob_sweep(&cfg, &choice, 2_000, &[40, 120], Some(&names)).unwrap();
        assert!(out.contains("40") && out.contains("120"));
    }
}
