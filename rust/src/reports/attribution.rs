//! Figure 11 reproduction: feature attribution.
//!
//! The paper uses SHAP; we substitute *permutation importance* (documented
//! in DESIGN.md): for each of the 50 features, shuffle its values across a
//! batch of real samples — separately for the to-be-predicted instruction
//! (slot 0) and for the context slots — and measure the mean absolute
//! change in the decoded latency predictions. Model-agnostic, same
//! question answered: which inputs drive the prediction.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::api::PredictorSpec;
use crate::des::SimConfig;
use crate::features::{feature_group, feature_name, ContextTracker, NUM_FEATURES};
use crate::predictor::LatencyPredictor;
use crate::stats::Table;

use super::{des_trace, pick_benches, REFERENCE_SEED};

/// Deterministic xorshift for the permutation (no external RNG crates).
fn shuffle_indices(n: usize, seed: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut s = seed | 1;
    for i in (1..n).rev() {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        idx.swap(i, (s as usize) % (i + 1));
    }
    idx
}

/// Mean decoded latency magnitude per sample row.
fn mean_abs_pred(preds: &[(u32, u32, u32)]) -> f64 {
    let s: u64 = preds.iter().map(|(f, e, st)| (*f + *e + *st) as u64).sum();
    s as f64 / preds.len().max(1) as f64
}

/// Result of one attribution run.
pub struct Attribution {
    /// (feature index, score for slot-0 permutation, score for context
    /// slots permutation).
    pub scores: Vec<(usize, f64, f64)>,
}

/// Compute permutation importances over `samples` encoded inputs drawn
/// from real benchmark traces.
pub fn attribution(
    cfg: &SimConfig,
    spec: &PredictorSpec,
    samples: usize,
    benches: Option<&[String]>,
) -> Result<Attribution> {
    let mut predictor = spec.build()?;
    let seq = predictor.seq_len();
    let width = seq * NUM_FEATURES;

    // Collect encoded inputs by replaying traces through the tracker.
    let mut inputs: Vec<f32> = Vec::new();
    let mut count = 0usize;
    'outer: for b in pick_benches(benches) {
        let (recs, _) = des_trace(cfg, &b, (samples * 2) as u64, REFERENCE_SEED);
        let mut tracker = ContextTracker::new(cfg);
        let mut buf = vec![0.0f32; width];
        for (k, r) in recs.iter().enumerate() {
            tracker.encode_input(&r.inst, &r.hist, seq, &mut buf);
            // Skip the cold-start prefix; keep every 3rd sample for variety.
            if k > 200 && k % 3 == 0 {
                inputs.extend_from_slice(&buf);
                count += 1;
                if count >= samples {
                    break 'outer;
                }
            }
            tracker.push(&r.inst, &r.hist, r.f_lat, r.e_lat, r.s_lat);
        }
    }
    let n = count;
    let base = predictor.predict(&inputs, n)?;
    let base_rows: Vec<(u32, u32, u32)> = base;

    let mut scores = Vec::with_capacity(NUM_FEATURES);
    let mut scratch = inputs.clone();
    for f in 0..NUM_FEATURES {
        // Slot-0 permutation.
        let perm = shuffle_indices(n, 0x5EED ^ f as u64);
        scratch.copy_from_slice(&inputs);
        for i in 0..n {
            scratch[i * width + f] = inputs[perm[i] * width + f];
        }
        let cur = predictor.predict(&scratch, n)?;
        let s0: f64 = cur
            .iter()
            .zip(&base_rows)
            .map(|(a, b)| {
                (a.0 as i64 - b.0 as i64).unsigned_abs()
                    + (a.1 as i64 - b.1 as i64).unsigned_abs()
                    + (a.2 as i64 - b.2 as i64).unsigned_abs()
            })
            .sum::<u64>() as f64
            / n as f64;

        // Context-slots permutation (all slots >= 1 at feature f).
        scratch.copy_from_slice(&inputs);
        for i in 0..n {
            for slot in 1..seq {
                let off = slot * NUM_FEATURES + f;
                scratch[i * width + off] = inputs[perm[i] * width + off];
            }
        }
        let cur = predictor.predict(&scratch, n)?;
        let sc: f64 = cur
            .iter()
            .zip(&base_rows)
            .map(|(a, b)| {
                (a.0 as i64 - b.0 as i64).unsigned_abs()
                    + (a.1 as i64 - b.1 as i64).unsigned_abs()
                    + (a.2 as i64 - b.2 as i64).unsigned_abs()
            })
            .sum::<u64>() as f64
            / n as f64;
        scores.push((f, s0, sc));
    }
    let _ = mean_abs_pred(&base_rows);
    Ok(Attribution { scores })
}

/// Render the Figure 11 report: top features + per-group totals for the
/// to-be-predicted instruction and for context instructions.
pub fn render(attr: &Attribution) -> String {
    let mut report = String::from("== Figure 11: feature attribution (permutation importance) ==\n");
    let mut by_score = attr.scores.clone();
    by_score.sort_by(|a, b| (b.1 + b.2).partial_cmp(&(a.1 + a.2)).unwrap());
    let mut table = Table::new(&["feature", "group", "slot0_score", "context_score"]);
    for (f, s0, sc) in by_score.iter().take(12) {
        table.row(vec![
            feature_name(*f),
            feature_group(*f).to_string(),
            format!("{s0:.3}"),
            format!("{sc:.3}"),
        ]);
    }
    report.push_str(&table.render());

    let mut groups: BTreeMap<&str, (f64, f64)> = BTreeMap::new();
    for (f, s0, sc) in &attr.scores {
        let e = groups.entry(feature_group(*f)).or_default();
        e.0 += s0;
        e.1 += sc;
    }
    let mut gt = Table::new(&["group", "slot0_total", "context_total"]);
    for (g, (s0, sc)) in groups {
        gt.row(vec![g.to_string(), format!("{s0:.3}"), format!("{sc:.3}")]);
    }
    report.push_str("\nPer-group totals (cf. Fig. 11a/11b):\n");
    report.push_str(&gt.render());
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_permutation() {
        let idx = shuffle_indices(100, 42);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(idx, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn attribution_table_predictor_finds_level_features() {
        // The analytical predictor depends hard on data_level/fetch_level
        // and not at all on register indices — attribution must rank a
        // level feature above every register feature.
        let cfg = SimConfig::default_o3();
        let spec = PredictorSpec::table(8);
        let names = vec!["mcf".to_string()];
        let attr = attribution(&cfg, &spec, 200, Some(&names)).unwrap();
        let score = |f: usize| attr.scores[f].1;
        let data_level = crate::features::DATA_HIST_BASE;
        let best_reg = (crate::features::REG_BASE..crate::features::REG_BASE + 14)
            .map(score)
            .fold(0.0f64, f64::max);
        assert!(
            score(data_level) > best_reg,
            "data_level {} <= best register {}",
            score(data_level),
            best_reg
        );
        let rendered = render(&attr);
        assert!(rendered.contains("data_level"));
    }
}
