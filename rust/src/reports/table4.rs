//! Table 4 reproduction: per-model instruction-prediction error (from
//! training metadata), computation intensity, and benchmark simulation
//! error against the DES, split into train-set / sim-set / all averages.

use std::path::Path;

use anyhow::Result;

use crate::api::{PredictorSpec, Simulation};
use crate::des::SimConfig;
use crate::stats::{cpi_error, mean, Table};

use super::{des_trace, pick_benches, REFERENCE_SEED};

/// Prediction-error metadata recorded by train.py in `<model>.meta`.
#[derive(Debug, Clone, Default)]
pub struct ModelMeta {
    pub model: String,
    pub mode: String,
    pub fetch_err: f64,
    pub exec_err: f64,
    pub store_err: f64,
    pub mflops: f64,
    pub train_seconds: f64,
}

impl ModelMeta {
    pub fn read(dir: &Path, tag: &str) -> Option<Self> {
        let text = std::fs::read_to_string(dir.join(format!("{tag}.meta"))).ok()?;
        let mut m = ModelMeta { model: tag.to_string(), ..Default::default() };
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match (it.next(), it.next()) {
                (Some("mode"), Some(v)) => m.mode = v.to_string(),
                (Some("fetch_err"), Some(v)) => m.fetch_err = v.parse().unwrap_or(0.0),
                (Some("exec_err"), Some(v)) => m.exec_err = v.parse().unwrap_or(0.0),
                (Some("store_err"), Some(v)) => m.store_err = v.parse().unwrap_or(0.0),
                (Some("mflops"), Some(v)) => m.mflops = v.parse().unwrap_or(0.0),
                (Some("train_seconds"), Some(v)) => m.train_seconds = v.parse().unwrap_or(0.0),
                _ => {}
            }
        }
        Some(m)
    }
}

/// One model's Table 4 row.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub meta: ModelMeta,
    pub train_avg_err: f64,
    pub sim_avg_err: f64,
    pub all_avg_err: f64,
    pub mips: f64,
}

/// Simulation error of one predictor across the suite. `n` instructions
/// per benchmark; parallel sub-traces sized `subtrace` (0 = sequential).
pub fn simulation_errors(
    cfg: &SimConfig,
    spec: &PredictorSpec,
    n: u64,
    subtrace: usize,
    benches: Option<&[String]>,
) -> Result<(Vec<(String, bool, f64, f64, f64)>, f64)> {
    // returns (bench, is_training, des_cpi, sim_cpi, err), overall mips
    let mut rows = Vec::new();
    let mut predictor = spec.build()?;
    let mut insts = 0u64;
    let mut wall = 0.0f64;
    for b in pick_benches(benches) {
        let (recs, des) = des_trace(cfg, &b, n, REFERENCE_SEED);
        let subs = if subtrace == 0 { 1 } else { (recs.len() / subtrace).max(1) };
        let out = Simulation::new()
            .records(&recs)
            .config(cfg)
            .predictor_ref(predictor.as_mut())
            .subtraces(subs)
            .run()?
            .outcome;
        let err = cpi_error(out.cpi(), des.cpi());
        rows.push((b.name.to_string(), b.training, des.cpi(), out.cpi(), err));
        insts += out.instructions;
        wall += out.wall_seconds;
    }
    let mips = if wall > 0.0 { insts as f64 / wall / 1e6 } else { 0.0 };
    Ok((rows, mips))
}

/// Build Table 4 for every model tag that has both `.meta` and `.export`
/// in `artifacts` (plus the analytical table baseline for context).
pub fn run(
    artifacts: &Path,
    models: &[String],
    cfg: &SimConfig,
    n: u64,
    subtrace: usize,
) -> Result<String> {
    let mut table = Table::new(&[
        "model", "output", "MFlops", "fetch_err", "exec_err", "store_err", "train_avg",
        "sim_avg", "all_avg", "MIPS",
    ]);
    let mut report = String::from("== Table 4: model accuracy & simulation error ==\n");
    for tag in models {
        let Some(meta) = ModelMeta::read(artifacts, tag) else {
            report.push_str(&format!("(skipping {tag}: no {tag}.meta in artifacts)\n"));
            continue;
        };
        let spec =
            PredictorSpec::ml(artifacts, tag).with_weights(artifacts.join(format!("{tag}.smw")));
        let (rows, mips) = simulation_errors(cfg, &spec, n, subtrace, None)?;
        let train: Vec<f64> = rows.iter().filter(|r| r.1).map(|r| r.4).collect();
        let sim: Vec<f64> = rows.iter().filter(|r| !r.1).map(|r| r.4).collect();
        let all: Vec<f64> = rows.iter().map(|r| r.4).collect();
        table.row(vec![
            tag.clone(),
            meta.mode.clone(),
            format!("{:.2}", meta.mflops),
            format!("{:.1}%", meta.fetch_err * 100.0),
            format!("{:.1}%", meta.exec_err * 100.0),
            format!("{:.1}%", meta.store_err * 100.0),
            format!("{:.1}%", mean(&train) * 100.0),
            format!("{:.1}%", mean(&sim) * 100.0),
            format!("{:.1}%", mean(&all) * 100.0),
            format!("{:.2}", mips),
        ]);
    }
    report.push_str(&table.render());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulation_errors_with_table_predictor() {
        let cfg = SimConfig::default_o3();
        let spec = PredictorSpec::table(16);
        let names: Vec<String> = vec!["exchange2".into(), "mcf".into()];
        let (rows, _mips) = simulation_errors(&cfg, &spec, 3_000, 0, Some(&names)).unwrap();
        assert_eq!(rows.len(), 2);
        for (name, _, des_cpi, sim_cpi, err) in rows {
            assert!(des_cpi > 0.0 && sim_cpi > 0.0, "{name}");
            assert!(err < 5.0, "{name} err {err} out of sanity band");
        }
    }

    #[test]
    fn meta_read_parses() {
        let dir = std::env::temp_dir().join("simnet_t4");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("c9.meta"),
            "model c9\nseq_len 32\nmode hyb\nfetch_err 0.05\nexec_err 0.04\nstore_err 0.01\nmflops 8.1\ntrain_seconds 120\n",
        )
        .unwrap();
        let m = ModelMeta::read(&dir, "c9").unwrap();
        assert_eq!(m.mode, "hyb");
        assert!((m.fetch_err - 0.05).abs() < 1e-9);
        assert!((m.mflops - 8.1).abs() < 1e-9);
        assert!((m.train_seconds - 120.0).abs() < 1e-9);
    }
}
