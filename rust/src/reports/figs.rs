//! Figure 5 / 6 / 10 reproductions.

use anyhow::Result;

use crate::api::{PredictorSpec, Simulation};
use crate::des::SimConfig;
use crate::stats::{cpi_error, mean, render_cpi_series, Table};
use crate::trace::TraceRecord;

use super::table4::ModelMeta;
use super::{des_trace, pick_benches, REFERENCE_SEED};

/// Figure 5: simulated CPI per benchmark, DES vs each predictor.
pub fn fig5(
    cfg: &SimConfig,
    specs: &[PredictorSpec],
    n: u64,
    subtrace: usize,
    benches: Option<&[String]>,
) -> Result<String> {
    let mut headers = vec!["benchmark".to_string(), "des_cpi".to_string()];
    for s in specs {
        headers.push(format!("{}_cpi", s.label()));
        headers.push(format!("{}_err", s.label()));
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);
    let mut predictors: Vec<_> = specs.iter().map(|s| s.build()).collect::<Result<_>>()?;
    let mut worst: Vec<(String, f64)> = vec![(String::new(), 0.0); specs.len()];
    let mut errs: Vec<Vec<f64>> = vec![Vec::new(); specs.len()];

    for b in pick_benches(benches) {
        let (recs, des) = des_trace(cfg, &b, n, REFERENCE_SEED);
        let mut cells = vec![b.name.to_string(), format!("{:.3}", des.cpi())];
        for (k, p) in predictors.iter_mut().enumerate() {
            let subs = if subtrace == 0 { 1 } else { (recs.len() / subtrace).max(1) };
            let out = Simulation::new()
                .records(&recs)
                .config(cfg)
                .predictor_ref(p.as_mut())
                .subtraces(subs)
                .run()?
                .outcome;
            let err = cpi_error(out.cpi(), des.cpi());
            errs[k].push(err);
            if err > worst[k].1 {
                worst[k] = (b.name.to_string(), err);
            }
            cells.push(format!("{:.3}", out.cpi()));
            cells.push(format!("{:.1}%", err * 100.0));
        }
        table.row(cells);
    }
    let mut report = String::from("== Figure 5: simulated benchmark CPIs ==\n");
    report.push_str(&table.render());
    for (k, c) in specs.iter().enumerate() {
        let gt10 = errs[k].iter().filter(|&&e| e > 0.10).count();
        report.push_str(&format!(
            "{}: avg err {:.1}%, {} / {} benchmarks over 10% (worst: {} {:.1}%)\n",
            c.label(),
            mean(&errs[k]) * 100.0,
            gt10,
            errs[k].len(),
            worst[k].0,
            worst[k].1 * 100.0
        ));
    }
    Ok(report)
}

/// Figure 6: CPI variation across execution windows, DES vs predictors.
/// `window` instructions per point (paper: 1M over 100M).
pub fn fig6(
    cfg: &SimConfig,
    specs: &[PredictorSpec],
    n: u64,
    window: u64,
    benches: Option<&[String]>,
) -> Result<String> {
    let mut report = String::from("== Figure 6: phase-level CPI curves ==\n");
    let mut predictors: Vec<_> = specs.iter().map(|s| s.build()).collect::<Result<_>>()?;
    for b in pick_benches(benches) {
        let (recs, _) = des_trace(cfg, &b, n, REFERENCE_SEED);
        // DES window series from the trace's own fetch latencies.
        let mut des_windows = Vec::new();
        let mut acc = 0u64;
        let mut cnt = 0u64;
        for r in &recs {
            acc += r.f_lat as u64;
            cnt += 1;
            if cnt == window {
                des_windows.push((cnt, acc));
                acc = 0;
                cnt = 0;
            }
        }
        if cnt > 0 {
            des_windows.push((cnt, acc));
        }
        report.push_str(&format!("--- {} ---\n", b.name));
        report.push_str(&render_cpi_series("des", &des_windows));
        for (k, p) in predictors.iter_mut().enumerate() {
            let out = Simulation::new()
                .records(&recs)
                .config(cfg)
                .predictor_ref(p.as_mut())
                .window(window)
                .run()?
                .outcome;
            report.push_str(&render_cpi_series(&specs[k].label(), &out.windows));
            // Max per-window CPI deviation (the dotted error lines).
            let max_dev = des_windows
                .iter()
                .zip(&out.windows)
                .map(|((dn, dc), (sn, sc))| {
                    let d = *dc as f64 / (*dn).max(1) as f64;
                    let s = *sc as f64 / (*sn).max(1) as f64;
                    (s - d).abs()
                })
                .fold(0.0f64, f64::max);
            report.push_str(&format!("  max |window CPI dev| vs des: {max_dev:.3}\n"));
        }
    }
    Ok(report)
}

/// Measure each model's simulation MIPS over a prepared trace (the
/// throughput half of Figure 10), shared by the CLI and the bench
/// harness. A model whose artifacts fail to *load* is skipped, but never
/// silently — the model and the load error are named on stderr (the
/// report degrades to the remaining models). A model that loads but then
/// fails to *simulate* is a real error and propagates.
pub fn fig10_sim_mips(
    artifacts: &std::path::Path,
    models: &[String],
    cfg: &SimConfig,
    recs: &[TraceRecord],
    subtraces: usize,
) -> Result<Vec<(String, f64)>> {
    let mut sim_mips = Vec::new();
    for m in models {
        match PredictorSpec::ml(artifacts, m).build() {
            Ok(mut p) => {
                let out = Simulation::new()
                    .records(recs)
                    .config(cfg)
                    .predictor_ref(p.as_mut())
                    .subtraces(subtraces)
                    .run()?;
                sim_mips.push((m.clone(), out.mips()));
            }
            Err(e) => eprintln!("fig10: skipping model {m}: failed to load: {e}"),
        }
    }
    Ok(sim_mips)
}

/// Figure 10: overall throughput (training + simulation amortization).
/// Uses the measured simulation MIPS and the training time recorded in the
/// model's meta; DES throughput is measured on the spot.
pub fn fig10(
    artifacts: &std::path::Path,
    models: &[String],
    cfg: &SimConfig,
    sim_mips: &[(String, f64)],
    des_mips: f64,
) -> Result<String> {
    let mut report = String::from("== Figure 10: overall throughput incl. training ==\n");
    let mut table = Table::new(&["instructions", "gem5(des)"]);
    let mut metas = Vec::new();
    for tag in models {
        if let Some(meta) = ModelMeta::read(artifacts, tag) {
            table = Table::new(&[]); // rebuilt below with dynamic headers
            metas.push(meta);
        }
    }
    let mut headers: Vec<String> = vec!["instructions".into(), "des".into()];
    for m in &metas {
        headers.push(m.model.clone());
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    table = Table::new(&hrefs);
    for exp in [8u32, 9, 10, 11, 12, 13] {
        let n = 10f64.powi(exp as i32);
        let mut cells = vec![format!("1e{exp}"), format!("{:.3} MIPS", des_mips)];
        for m in &metas {
            let mips = sim_mips
                .iter()
                .find(|(tag, _)| *tag == m.model)
                .map(|(_, v)| *v)
                .unwrap_or(0.0);
            let train_s = m.train_seconds.max(1.0);
            let overall = n / (train_s + n / (mips * 1e6)) / 1e6;
            cells.push(format!("{overall:.3} MIPS"));
        }
        table.row(cells);
    }
    report.push_str(&table.render());
    report.push_str(&format!(
        "crossover vs des at N where train_time = N*(1/des - 1/sim); \
         des={des_mips:.3} MIPS\n"
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_runs_with_table_predictor() {
        let cfg = SimConfig::default_o3();
        let names = vec!["leela".to_string()];
        let out = fig5(&cfg, &[PredictorSpec::table(16)], 2_000, 0, Some(&names)).unwrap();
        assert!(out.contains("leela"));
        assert!(out.contains("avg err"));
    }

    #[test]
    fn fig6_runs_with_table_predictor() {
        let cfg = SimConfig::default_o3();
        let names = vec!["bwaves".to_string()];
        let out = fig6(&cfg, &[PredictorSpec::table(16)], 4_000, 1_000, Some(&names)).unwrap();
        assert!(out.contains("bwaves"));
        assert!(out.contains("max |window CPI dev|"));
    }
}
