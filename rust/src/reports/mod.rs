//! Paper-reproduction reports: one entry point per table/figure of the
//! evaluation section (see DESIGN.md's experiment index). Shared by the
//! `repro` CLI and the `cargo bench` harnesses.

pub mod attribution;
pub mod figs;
pub mod sweeps;
pub mod table4;

use crate::des::{simulate, DesStats, SimConfig};
use crate::trace::TraceRecord;
use crate::workload::{suite, Benchmark};

/// The "reference workload" input seed used for simulation accuracy runs
/// (dataset generation uses seed 0 — the "test workload").
pub const REFERENCE_SEED: u64 = 1;

/// Run the DES over a benchmark and collect (records, stats). This is the
/// ground-truth generator used throughout the reports; results are
/// deterministic so no caching subtleties arise.
pub fn des_trace(
    cfg: &SimConfig,
    bench: &Benchmark,
    n: u64,
    seed: u64,
) -> (Vec<TraceRecord>, DesStats) {
    let wl = bench.workload(seed);
    let mut recs = Vec::with_capacity(n as usize);
    let stats = simulate(cfg, wl.stream(), n, |e| recs.push(TraceRecord::from(e)));
    (recs, stats)
}

/// All 25 benchmarks, or a filtered subset by names.
pub fn pick_benches(names: Option<&[String]>) -> Vec<Benchmark> {
    let all = suite();
    match names {
        None => all,
        Some(ns) => all.into_iter().filter(|b| ns.iter().any(|n| n == b.name)).collect(),
    }
}

/// Simulated wattage model for the power-efficiency comparison (§4.2):
/// the DES runs on a CPU socket; the ML simulator additionally books the
/// accelerator's TDP. Absolute numbers are a model, ratios are the point.
pub const CPU_TDP_WATTS: f64 = 225.0;
pub const ACCEL_TDP_WATTS: f64 = 400.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::find;

    #[test]
    fn des_trace_deterministic_across_calls() {
        let cfg = SimConfig::default_o3();
        let b = find("xz").unwrap();
        let (r1, s1) = des_trace(&cfg, &b, 3000, 0);
        let (r2, s2) = des_trace(&cfg, &b, 3000, 0);
        assert_eq!(s1.cycles, s2.cycles);
        assert_eq!(r1.len(), r2.len());
        assert_eq!(r1[100], r2[100]);
    }

    #[test]
    fn pick_benches_filters() {
        let all = pick_benches(None);
        assert_eq!(all.len(), 25);
        let some = pick_benches(Some(&["mcf".to_string(), "gcc".to_string()]));
        assert_eq!(some.len(), 2);
    }
}
