// The `portable-simd` cargo feature swaps the kernel accumulator onto
// `std::simd` (nightly-only); stable builds use the autovectorized form.
#![cfg_attr(feature = "portable-simd", feature(portable_simd))]

//! # SimNet-RS
//!
//! A from-scratch reproduction of *SimNet: Accurate and High-Performance
//! Computer Architecture Simulation using Deep Learning* (Li et al.) as a
//! three-layer rust + JAX + Pallas system.
//!
//! - [`isa`] / [`workload`]: synthetic ARMv8-like ISA and the SPEC-like
//!   benchmark suite that drives everything.
//! - [`des`]: the reference cycle-level out-of-order simulator (the "gem5"
//!   this repo's ML models learn from and are validated against).
//! - [`history`]: lightweight history-context simulation (caches / TLBs /
//!   branch predictors as lookup structures only).
//! - [`features`]: the 50-feature instruction encoding and context
//!   (processor-queue / memory-write-queue) tracking.
//! - [`trace`]: binary trace (`.smt`) and ML dataset (`.smd`) formats.
//! - [`tensor`]: the `.smw` weight tensor container.
//! - [`runtime`]: PJRT executable loading/execution (the `xla` crate).
//! - [`predictor`]: latency-predictor abstraction — ML (PJRT), native
//!   pure-Rust NN inference, and table based implementations.
//! - [`coordinator`]: the SimNet simulators (sequential + parallel) and the
//!   batching/worker orchestration.
//! - [`api`]: the unified session API — [`api::Simulation`] builder,
//!   [`api::PredictorSpec`], and the machine-readable [`api::SimReport`]
//!   every CLI/report/bench caller drives runs through.
//! - [`server`]: the resident job server — warm predictor registry,
//!   priority admission queue, newline-delimited JSON protocol, and
//!   cross-tenant co-batching through one shared engine.
//! - [`stats`]: error metrics, CPI series, report generation.

pub mod api;
pub mod coordinator;
pub mod des;
pub mod features;
pub mod history;
pub mod isa;
pub mod predictor;
pub mod reports;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod tensor;
pub mod trace;
pub mod workload;
