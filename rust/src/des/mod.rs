//! Reference discrete-event simulator — the "gem5" of this repo.
//!
//! A cycle-level model of an out-of-order superscalar CPU (paper Table 2):
//! wide fetch limited by I-cache/ITLB behaviour and branch mispredictions,
//! register renaming via a ready-time scoreboard, an issue queue with
//! per-class functional units, load/store queues with store-to-load
//! forwarding, MSHR-limited caches, in-order commit, and post-commit store
//! writeback through the store queue.
//!
//! The model is *event-driven per instruction* (every stage time is
//! computed analytically as the instruction flows through), which makes it
//! O(1) per instruction while still producing the paper's three label
//! latencies per instruction:
//!
//! * `F` fetch latency — cycles between the previous instruction's fetch
//!   and this one's (Eq. 1's summand),
//! * `E` execution latency — fetch until ready-to-retire from the ROB,
//! * `S` store latency — fetch until the post-commit memory write
//!   completes (ready-to-retire from the SQ).
//!
//! Cache/TLB/branch *outcomes* come from the shared [`crate::history`]
//! simulator so that trace features and DES timing always agree.

pub mod config;
mod core;

pub use self::core::{DesCpu, DesStats, ExecutedInst};
pub use config::{BpChoice, CacheParams, PrefetchParams, SimConfig, TlbParams};

use crate::isa::Inst;

/// Run the DES over `n` instructions from `stream`, invoking `sink` for
/// every retired instruction. Returns the run statistics.
pub fn simulate<I, F>(cfg: &SimConfig, stream: I, n: u64, mut sink: F) -> DesStats
where
    I: Iterator<Item = Inst>,
    F: FnMut(&ExecutedInst),
{
    let mut cpu = DesCpu::new(cfg);
    for inst in stream.take(n as usize) {
        let exec = cpu.step(&inst);
        sink(&exec);
    }
    cpu.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{find, suite};

    #[test]
    fn cpi_in_reasonable_band_for_all_benchmarks() {
        let cfg = SimConfig::default_o3();
        for b in suite() {
            let wl = b.workload(0);
            let stats = simulate(&cfg, wl.stream(), 20_000, |_| {});
            let cpi = stats.cpi();
            assert!(
                (0.3..40.0).contains(&cpi),
                "{}: implausible CPI {cpi}",
                b.name
            );
        }
    }

    #[test]
    fn deterministic() {
        let cfg = SimConfig::default_o3();
        let b = find("mcf").unwrap();
        let s1 = simulate(&cfg, b.workload(0).stream(), 30_000, |_| {});
        let s2 = simulate(&cfg, b.workload(0).stream(), 30_000, |_| {});
        assert_eq!(s1.cycles, s2.cycles);
        assert_eq!(s1.instructions, s2.instructions);
    }

    #[test]
    fn memory_bound_slower_than_compute_bound() {
        let cfg = SimConfig::default_o3();
        let cpi = |name: &str| {
            let b = find(name).unwrap();
            simulate(&cfg, b.workload(0).stream(), 100_000, |_| {}).cpi()
        };
        let mcf = cpi("mcf"); // pointer chaser, 32MB working set
        let exchange2 = cpi("exchange2"); // small-footprint int compute
        assert!(
            mcf > exchange2 * 1.3,
            "mcf={mcf:.2} should be well above exchange2={exchange2:.2}"
        );
    }

    #[test]
    fn eq1_holds_sum_of_fetch_latencies() {
        // Paper Eq. 1: total time = sum(F_i) + Delta, where Delta is the
        // drain time of the last instructions.
        let cfg = SimConfig::default_o3();
        let b = find("gcc").unwrap();
        let mut sum_f: u64 = 0;
        let stats = simulate(&cfg, b.workload(0).stream(), 50_000, |e| {
            sum_f += e.f_lat as u64;
        });
        assert!(stats.cycles >= sum_f, "cycles {} < sum F {}", stats.cycles, sum_f);
        let delta = stats.cycles - sum_f;
        // Drain is bounded by the worst-case lifetime of one window of
        // instructions, far below the total for 50k instructions.
        assert!(
            (delta as f64) < 0.05 * stats.cycles as f64,
            "delta {delta} too large vs {}",
            stats.cycles
        );
    }

    #[test]
    fn latency_invariants_per_instruction() {
        let cfg = SimConfig::default_o3();
        let b = find("xalancbmk").unwrap();
        simulate(&cfg, b.workload(0).stream(), 50_000, |e| {
            assert!(e.e_lat >= 1, "E must be positive");
            if e.inst.op.is_store() {
                assert!(e.s_lat >= e.e_lat, "store S {} < E {}", e.s_lat, e.e_lat);
            } else {
                assert_eq!(e.s_lat, 0, "non-store has S latency");
            }
        });
    }

    #[test]
    fn a64fx_config_runs() {
        let cfg = SimConfig::a64fx();
        let b = find("bwaves").unwrap();
        let stats = simulate(&cfg, b.workload(0).stream(), 30_000, |_| {});
        assert!(stats.cpi() > 0.2 && stats.cpi() < 60.0, "cpi={}", stats.cpi());
    }

    #[test]
    fn bigger_rob_not_slower() {
        let base = SimConfig::default_o3();
        let mut big = SimConfig::default_o3();
        big.rob_entries = 120;
        big.iq_entries = 96;
        big.lq_entries = 48;
        big.sq_entries = 48;
        let b = find("namd").unwrap();
        let c_base = simulate(&base, b.workload(0).stream(), 80_000, |_| {}).cycles;
        let c_big = simulate(&big, b.workload(0).stream(), 80_000, |_| {}).cycles;
        assert!(
            c_big as f64 <= c_base as f64 * 1.02,
            "bigger window slower: {c_big} vs {c_base}"
        );
    }
}
