//! The out-of-order CPU timing model.
//!
//! Each dynamic instruction flows through the model exactly once; every
//! pipeline event time (fetch, dispatch, issue, complete, commit, store
//! writeback) is computed analytically from resource-availability rings.
//! This keeps the simulator O(1) per instruction while modelling:
//!
//! * fetch bandwidth, I-cache/ITLB stalls, taken-branch fetch breaks,
//!   misprediction redirect bubbles, serializing drains,
//! * a finite fetch/decode buffer that backpressures fetch when dispatch
//!   stalls (this is what makes fetch latency — the paper's `F` — reflect
//!   backend congestion),
//! * ROB/IQ/LQ/SQ occupancy, issue bandwidth, per-class functional units
//!   (pipelined and unpipelined),
//! * operand readiness via a register ready-time scoreboard,
//! * D-cache/DTLB latencies with MSHR-limited misses and store-to-load
//!   forwarding,
//! * in-order commit bandwidth and post-commit store writeback.

use super::config::SimConfig;
use crate::history::{HistoryInfo, HistorySim};
use crate::isa::{FuClass, Inst, OpClass, NUM_REGS, REG_NONE};

/// One retired instruction with its labels — what gets written to traces.
#[derive(Debug, Clone, Copy)]
pub struct ExecutedInst {
    pub inst: Inst,
    pub hist: HistoryInfo,
    /// Absolute cycle the instruction was fetched.
    pub fetch_cycle: u64,
    /// Fetch latency `F`: cycles since the previous instruction's fetch.
    pub f_lat: u32,
    /// Execution latency `E`: fetch -> ready to retire from ROB.
    pub e_lat: u32,
    /// Store latency `S`: fetch -> memory write complete (stores only; 0
    /// otherwise).
    pub s_lat: u32,
}

/// Aggregate run statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct DesStats {
    pub instructions: u64,
    /// Total cycles until the last instruction fully left the machine.
    pub cycles: u64,
    pub mispredicts: u64,
    pub l1d_miss: u64,
    pub mem_accesses: u64,
}

impl DesStats {
    pub fn cpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.cycles as f64 / self.instructions as f64
        }
    }

    pub fn ipc(&self) -> f64 {
        let c = self.cpi();
        if c == 0.0 {
            0.0
        } else {
            1.0 / c
        }
    }
}

/// Capacity-limited resource: slot `i mod cap` is reusable once its previous
/// occupant releases it. Allocation order == release recording order, which
/// holds for every queue we model (ROB/IQ/LQ/SQ/fetch buffer are all
/// allocated in program order and released in a program-order-derived time).
struct SlotRing {
    free_at: Vec<u64>,
    idx: usize,
}

impl SlotRing {
    fn new(cap: usize) -> Self {
        SlotRing { free_at: vec![0; cap.max(1)], idx: 0 }
    }

    /// Earliest time an allocation wanted at `want` can happen.
    #[inline]
    fn earliest(&self, want: u64) -> u64 {
        want.max(self.free_at[self.idx])
    }

    /// Record the release time of the slot just allocated and advance.
    #[inline]
    fn commit(&mut self, release: u64) {
        self.free_at[self.idx] = release;
        self.idx = (self.idx + 1) % self.free_at.len();
    }
}

/// Bandwidth limiter: at most `width` events per cycle.
struct BandwidthRing {
    last: Vec<u64>,
    idx: usize,
}

impl BandwidthRing {
    fn new(width: u32) -> Self {
        BandwidthRing { last: vec![0; width.max(1) as usize], idx: 0 }
    }

    /// Allocate an event no earlier than `want`; returns the granted cycle.
    #[inline]
    fn alloc(&mut self, want: u64) -> u64 {
        let t = want.max(self.last[self.idx] + 1);
        self.last[self.idx] = t;
        self.idx = (self.idx + 1) % self.last.len();
        t
    }
}

/// Functional-unit pool for one class.
struct FuPool {
    busy_until: Vec<u64>,
}

impl FuPool {
    fn new(count: u32) -> Self {
        FuPool { busy_until: vec![0; count.max(1) as usize] }
    }

    /// Acquire a unit at `want`; occupies it for `occupy` cycles (1 for
    /// pipelined units, the full latency for unpipelined ones).
    fn acquire(&mut self, want: u64, occupy: u64) -> u64 {
        let (i, &free) =
            self.busy_until.iter().enumerate().min_by_key(|(_, &t)| t).unwrap();
        let start = want.max(free);
        self.busy_until[i] = start + occupy;
        start
    }
}

/// MSHR-limited miss path: at most `cap` outstanding misses.
struct MshrQueue {
    inflight: Vec<u64>,
    cap: usize,
}

impl MshrQueue {
    fn new(cap: usize) -> Self {
        MshrQueue { inflight: Vec::with_capacity(cap.max(1)), cap: cap.max(1) }
    }

    /// Start a miss at `want` lasting `latency`; returns its actual start
    /// (delayed if all MSHRs are busy).
    fn access(&mut self, want: u64, latency: u64) -> u64 {
        // Retire finished misses.
        self.inflight.retain(|&t| t > want);
        let start = if self.inflight.len() < self.cap {
            want
        } else {
            let min = *self.inflight.iter().min().unwrap();
            let i = self.inflight.iter().position(|&t| t == min).unwrap();
            self.inflight.swap_remove(i);
            want.max(min)
        };
        self.inflight.push(start + latency);
        start
    }
}

/// Store-queue entry kept for store-to-load forwarding.
#[derive(Debug, Clone, Copy)]
struct SqEntry {
    addr: u64,
    size: u8,
    /// When the store's data is available for forwarding.
    data_ready: u64,
    /// When the store leaves the SQ (memory write complete).
    write_complete: u64,
}

/// The CPU model. Feed instructions in program order via [`DesCpu::step`].
pub struct DesCpu {
    cfg: SimConfig,
    hist: HistorySim,
    // frontend
    fetch_bw: BandwidthRing,
    frontend_buf: SlotRing,
    /// Floor on the next fetch (redirects, serialization, taken branches).
    fetch_floor: u64,
    last_fetch: u64,
    last_fetch_line: u64,
    // backend resources
    rob: SlotRing,
    iq: SlotRing,
    lq: SlotRing,
    sq: SlotRing,
    issue_bw: BandwidthRing,
    commit_bw: BandwidthRing,
    fus: [FuPool; 8],
    l1d_mshr: MshrQueue,
    l1i_mshr: MshrQueue,
    // state
    reg_ready: [u64; NUM_REGS],
    sq_entries: Vec<SqEntry>,
    /// In-order commit front: commit times are non-decreasing.
    last_commit: u64,
    /// Completion time of the latest memory op (for barriers).
    last_mem_complete: u64,
    /// Memory ops may not issue before this (set by barriers).
    barrier_floor: u64,
    /// Max completion time over all instructions (for serializing ops).
    max_complete: u64,
    /// Machine-drain time: when the last instruction fully left.
    end_time: u64,
    stats: DesStats,
}

impl DesCpu {
    pub fn new(cfg: &SimConfig) -> Self {
        DesCpu {
            hist: HistorySim::new(cfg),
            fetch_bw: BandwidthRing::new(cfg.fetch_width),
            frontend_buf: SlotRing::new((cfg.fetch_width * cfg.frontend_depth * 2) as usize),
            fetch_floor: 0,
            last_fetch: 0,
            last_fetch_line: u64::MAX,
            rob: SlotRing::new(cfg.rob_entries),
            iq: SlotRing::new(cfg.iq_entries),
            lq: SlotRing::new(cfg.lq_entries),
            sq: SlotRing::new(cfg.sq_entries),
            issue_bw: BandwidthRing::new(cfg.issue_width),
            commit_bw: BandwidthRing::new(cfg.commit_width),
            fus: cfg.fu_counts.map(FuPool::new),
            l1d_mshr: MshrQueue::new(cfg.l1d.mshrs),
            l1i_mshr: MshrQueue::new(cfg.l1i.mshrs),
            reg_ready: [0; NUM_REGS],
            sq_entries: Vec::new(),
            last_commit: 0,
            last_mem_complete: 0,
            barrier_floor: 0,
            max_complete: 0,
            end_time: 0,
            stats: DesStats::default(),
            cfg: cfg.clone(),
        }
    }

    /// TLB penalty in cycles given a translation result encoded as the
    /// history sim reports it.
    fn tlb_penalty(l2_latency: u32, walk_latency: u32, level: u8, walk: &[bool; 3]) -> u64 {
        match level {
            0 => 0,
            1 => l2_latency as u64,
            _ => {
                let mut pen = l2_latency as u64;
                for &miss in walk {
                    pen += if miss { walk_latency as u64 } else { 4 };
                }
                pen
            }
        }
    }

    /// Advance the model by one instruction; returns its timing record.
    pub fn step(&mut self, inst: &Inst) -> ExecutedInst {
        let cfg = self.cfg.clone();
        let hist = self.hist.process(inst);
        self.stats.instructions += 1;
        self.stats.mispredicts += hist.mispredict as u64;
        if inst.op.is_mem() {
            self.stats.mem_accesses += 1;
            self.stats.l1d_miss += (hist.data_level > 1) as u64;
        }

        // ------------------------------------------------------------
        // FETCH
        // ------------------------------------------------------------
        let mut want = self.fetch_floor;
        // Finite frontend buffer: can't fetch further ahead of dispatch.
        want = self.frontend_buf.earliest(want);
        // I-cache / ITLB stalls apply when a new line is touched.
        let line = inst.fetch_line();
        if line != self.last_fetch_line {
            let itlb_pen = Self::tlb_penalty(
                cfg.itlb.l2_latency,
                cfg.itlb.walk_latency,
                // fetch_walk flags are only set on a full walk; recover the
                // TLB level from them plus the fetch level heuristically:
                // the history sim stores walk misses only for full walks.
                if hist.fetch_walk.iter().any(|&m| m) { 2 } else { 0 },
                &hist.fetch_walk,
            );
            let line_lat = if hist.fetch_level > 1 {
                let miss_lat = (cfg.level_latency(&cfg.l1i, hist.fetch_level)
                    - cfg.l1i.hit_latency) as u64;
                let start = self.l1i_mshr.access(want + itlb_pen, miss_lat);
                start + miss_lat - want
            } else {
                itlb_pen
            };
            want += line_lat;
            self.last_fetch_line = line;
        }
        let fetch = self.fetch_bw.alloc(want.max(self.last_fetch));
        let f_lat = (fetch - self.last_fetch) as u32;
        self.last_fetch = fetch;

        // Taken control flow ends the fetch group: next fetch is at least
        // the following cycle (no fetching across a taken branch).
        if inst.is_control() && inst.taken {
            self.fetch_floor = self.fetch_floor.max(fetch + 1);
        }

        // ------------------------------------------------------------
        // DISPATCH (rename + ROB/IQ/LQ/SQ allocation)
        // ------------------------------------------------------------
        let mut dispatch = fetch + cfg.frontend_depth as u64;
        dispatch = self.rob.earliest(dispatch);
        dispatch = self.iq.earliest(dispatch);
        if inst.is_load() {
            dispatch = self.lq.earliest(dispatch);
        }
        if inst.is_store() {
            dispatch = self.sq.earliest(dispatch);
        }

        // ------------------------------------------------------------
        // ISSUE (operands + FU + issue bandwidth)
        // ------------------------------------------------------------
        let mut ready = dispatch + 1;
        for &r in &inst.srcs {
            if r != REG_NONE {
                ready = ready.max(self.reg_ready[r as usize]);
            }
        }
        if inst.op.is_mem() {
            ready = ready.max(self.barrier_floor);
        }
        let fu = inst.op.fu_class();
        let exec_lat = inst.op.exec_latency() as u64;
        let start = if fu != FuClass::None {
            let occupy = if inst.op.fu_pipelined() { 1 } else { exec_lat };
            self.fus[fu as usize].acquire(ready, occupy)
        } else {
            ready
        };
        let issue = self.issue_bw.alloc(start);

        // ------------------------------------------------------------
        // EXECUTE / COMPLETE
        // ------------------------------------------------------------
        let dtlb_pen = if inst.op.is_mem() {
            Self::tlb_penalty(
                cfg.dtlb.l2_latency,
                cfg.dtlb.walk_latency,
                if hist.data_walk.iter().any(|&m| m) { 2 } else { 0 },
                &hist.data_walk,
            )
        } else {
            0
        };
        let complete = match inst.op {
            OpClass::Load => {
                let addr_ready = issue + 1 + dtlb_pen;
                // Store-to-load forwarding: youngest older store to the
                // same (8B-aligned) address still in the SQ.
                let fwd = self
                    .sq_entries
                    .iter()
                    .rev()
                    .find(|s| {
                        s.write_complete > addr_ready && (s.addr >> 3) == (inst.mem_addr >> 3)
                    })
                    .map(|s| s.data_ready);
                if let Some(data_ready) = fwd {
                    addr_ready.max(data_ready) + 1
                } else if hist.data_level > 1 {
                    let miss_lat =
                        (cfg.level_latency(&cfg.l1d, hist.data_level) - cfg.l1d.hit_latency) as u64;
                    let begin = self.l1d_mshr.access(addr_ready, miss_lat);
                    begin + cfg.l1d.hit_latency as u64 + miss_lat
                } else {
                    addr_ready + cfg.l1d.hit_latency as u64
                }
            }
            OpClass::Store => issue + 1 + dtlb_pen, // address+data staged; write is post-commit
            OpClass::MemBarrier => (issue + 1).max(self.last_mem_complete),
            OpClass::Serialize => (issue + 1).max(self.max_complete),
            _ => issue + exec_lat,
        };
        self.max_complete = self.max_complete.max(complete);
        if inst.op.is_mem() {
            self.last_mem_complete = self.last_mem_complete.max(complete);
        }
        if inst.op.is_barrier() {
            self.barrier_floor = self.barrier_floor.max(complete);
        }
        for &r in &inst.dsts {
            if r != REG_NONE {
                self.reg_ready[r as usize] = complete;
            }
        }

        // ------------------------------------------------------------
        // COMMIT (in order) and post-commit store writeback
        // ------------------------------------------------------------
        let commit = self.commit_bw.alloc((complete + 1).max(self.last_commit));
        self.last_commit = commit;

        // Redirect the frontend on a mispredicted control op: fetch resumes
        // once the branch resolves (complete) plus the redirect penalty.
        if hist.mispredict {
            self.fetch_floor =
                self.fetch_floor.max(complete + cfg.redirect_penalty as u64);
            // The frontend restarts at a new line.
            self.last_fetch_line = u64::MAX;
        }
        // Serializing instructions drain: nothing fetches until they commit.
        if inst.op.is_serializing() {
            self.fetch_floor = self.fetch_floor.max(commit + 1);
        }

        let mut s_lat = 0u32;
        let mut leave = commit;
        if inst.is_store() {
            // Post-commit write through the SQ; pays the D-cache level
            // latency (MSHR-limited on misses).
            let write_lat = if hist.data_level > 1 {
                let miss_lat =
                    (cfg.level_latency(&cfg.l1d, hist.data_level) - cfg.l1d.hit_latency) as u64;
                let begin = self.l1d_mshr.access(commit, miss_lat);
                (begin - commit) + cfg.l1d.hit_latency as u64 + miss_lat
            } else {
                cfg.l1d.hit_latency as u64
            };
            let write_complete = commit + 1 + write_lat;
            leave = write_complete;
            s_lat = (write_complete - fetch) as u32;
            if self.sq_entries.len() >= cfg.sq_entries {
                self.sq_entries.remove(0);
            }
            self.sq_entries.push(SqEntry {
                addr: inst.mem_addr,
                size: inst.mem_size,
                data_ready: complete,
                write_complete,
            });
        }

        // Release resources in allocation order.
        self.frontend_buf.commit(dispatch);
        self.rob.commit(commit);
        self.iq.commit(issue + 1);
        if inst.is_load() {
            self.lq.commit(complete + 1);
        }
        if inst.is_store() {
            self.sq.commit(leave);
        }

        self.end_time = self.end_time.max(leave);
        ExecutedInst {
            inst: *inst,
            hist,
            fetch_cycle: fetch,
            f_lat,
            e_lat: (complete - fetch) as u32,
            s_lat,
        }
    }

    /// Finish the run and return statistics (total time includes the drain
    /// of in-flight instructions — the paper's `Delta` in Eq. 1).
    pub fn finish(mut self) -> DesStats {
        self.stats.cycles = self.end_time;
        self.stats
    }

    /// Borrow the embedded history simulator (for feature consistency
    /// checks in tests).
    pub fn history(&self) -> &HistorySim {
        &self.hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    fn cpu() -> DesCpu {
        DesCpu::new(&SimConfig::default_o3())
    }

    fn alu(dst: i8, src: i8) -> Inst {
        let mut i = Inst { pc: 0x1000, op: OpClass::IntAlu, ..Default::default() };
        i.dsts[0] = dst;
        i.srcs[0] = src;
        i
    }

    #[test]
    fn dependent_chain_serializes() {
        let mut c = cpu();
        // r1 <- r0; r2 <- r1; r3 <- r2 ... each must wait for the previous.
        let mut completes = Vec::new();
        for k in 0..8i8 {
            let mut i = alu(k + 1, k);
            i.pc = 0x1000 + 4 * k as u64;
            let e = c.step(&i);
            completes.push(e.fetch_cycle + e.e_lat as u64);
        }
        for w in completes.windows(2) {
            assert!(w[1] > w[0], "dependent op completed no later: {completes:?}");
        }
    }

    #[test]
    fn independent_ops_overlap() {
        let mut c = cpu();
        let mut e_lats = Vec::new();
        for k in 0..8i8 {
            let mut i = alu(k + 1, 0); // all read r0, write distinct regs
            i.pc = 0x1000 + 4 * k as u64;
            e_lats.push(c.step(&i).e_lat);
        }
        // Independent ALU ops should have similar E (no chain growth).
        let spread = e_lats.iter().max().unwrap() - e_lats.iter().min().unwrap();
        assert!(spread <= 4, "independent ops serialized: {e_lats:?}");
    }

    #[test]
    fn div_longer_than_alu() {
        let mut c = cpu();
        let a = c.step(&alu(1, 0)).e_lat;
        let mut d = alu(2, 0);
        d.pc = 0x1004;
        d.op = OpClass::IntDiv;
        let dv = c.step(&d).e_lat;
        assert!(dv > a + 5, "div {dv} vs alu {a}");
    }

    #[test]
    fn cold_load_pays_memory_latency() {
        let mut c = cpu();
        let mut ld = Inst {
            pc: 0x2000,
            op: OpClass::Load,
            mem_addr: 0x5000_0000,
            mem_size: 8,
            ..Default::default()
        };
        ld.dsts[0] = 1;
        let e = c.step(&ld);
        let cfg = SimConfig::default_o3();
        assert!(
            e.e_lat as u32 >= cfg.mem_latency,
            "cold load E {} < mem latency {}",
            e.e_lat,
            cfg.mem_latency
        );
        // Warm load to the same line is far cheaper.
        let mut ld2 = ld;
        ld2.pc = 0x2004;
        ld2.mem_addr = 0x5000_0008;
        let e2 = c.step(&ld2);
        assert!(e2.e_lat < e.e_lat / 2, "warm {} vs cold {}", e2.e_lat, e.e_lat);
    }

    #[test]
    fn store_to_load_forwarding_beats_cache() {
        let mut c = cpu();
        // Store to addr, then immediately load it back: the load should
        // forward (fast) despite the line being cold in cache for the load.
        let mut st = Inst {
            pc: 0x3000,
            op: OpClass::Store,
            mem_addr: 0x6000_0000,
            mem_size: 8,
            ..Default::default()
        };
        st.srcs[0] = 1;
        c.step(&st);
        let mut ld = Inst {
            pc: 0x3004,
            op: OpClass::Load,
            mem_addr: 0x6000_0000,
            mem_size: 8,
            ..Default::default()
        };
        ld.dsts[0] = 2;
        let e = c.step(&ld);
        let cfg = SimConfig::default_o3();
        assert!(
            (e.e_lat as u32) < cfg.mem_latency,
            "forwarded load paid memory latency: {}",
            e.e_lat
        );
    }

    #[test]
    fn store_has_s_latency() {
        let mut c = cpu();
        let mut st = Inst {
            pc: 0x4000,
            op: OpClass::Store,
            mem_addr: 0x7000_0000,
            mem_size: 8,
            ..Default::default()
        };
        st.srcs[0] = 1;
        let e = c.step(&st);
        assert!(e.s_lat > e.e_lat);
    }

    #[test]
    fn fetch_latency_monotone_time() {
        let mut c = cpu();
        let mut last_fetch = 0;
        for k in 0..100u64 {
            let mut i = alu(1, 0);
            i.pc = 0x1000 + 4 * (k % 16);
            let e = c.step(&i);
            assert!(e.fetch_cycle >= last_fetch);
            assert_eq!(e.fetch_cycle - last_fetch, e.f_lat as u64);
            last_fetch = e.fetch_cycle;
        }
    }

    #[test]
    fn mispredicted_branch_creates_fetch_bubble() {
        let mut c = cpu();
        // Warm up with ALU ops, then a cold indirect branch (guaranteed BTB
        // miss -> mispredict) followed by another op: the op after the
        // branch must see a large F.
        for k in 0..6i8 {
            let mut i = alu(1, 0);
            i.pc = 0x100 + 4 * k as u64;
            c.step(&i);
        }
        let br = Inst {
            pc: 0x200,
            op: OpClass::IndirectBranch,
            target: 0x9000,
            taken: true,
            ..Default::default()
        };
        let eb = c.step(&br);
        assert!(eb.hist.mispredict, "cold indirect must mispredict");
        let mut after = alu(2, 0);
        after.pc = 0x9000;
        let ea = c.step(&after);
        assert!(
            ea.f_lat as u32 >= SimConfig::default_o3().redirect_penalty,
            "no bubble after mispredict: F={}",
            ea.f_lat
        );
    }

    #[test]
    fn serializing_op_drains() {
        let mut c = cpu();
        for k in 0..4i8 {
            let mut i = alu(1, 0);
            i.pc = 0x100 + 4 * k as u64;
            c.step(&i);
        }
        let ser = Inst { pc: 0x300, op: OpClass::Serialize, ..Default::default() };
        c.step(&ser);
        let mut after = alu(2, 0);
        after.pc = 0x304;
        let ea = c.step(&after);
        assert!(ea.f_lat > 2, "serialize did not stall fetch: F={}", ea.f_lat);
    }
}
