//! Simulated processor configurations (paper Table 2).

/// Parameters of one cache level (tag behaviour + timing).
#[derive(Debug, Clone)]
pub struct CacheParams {
    /// Total size in bytes.
    pub size: u64,
    /// Associativity (ways).
    pub ways: usize,
    /// Line size in bytes.
    pub line: u64,
    /// Number of MSHRs (outstanding misses).
    pub mshrs: usize,
    /// Access (hit) latency in cycles.
    pub hit_latency: u32,
}

impl CacheParams {
    pub fn sets(&self) -> usize {
        (self.size / self.line) as usize / self.ways
    }
}

/// Parameters of a 2-stage TLB (paper: "2-stage TLBs, 1KB TLB caches").
#[derive(Debug, Clone)]
pub struct TlbParams {
    /// First-stage TLB entries (fully busy path).
    pub l1_entries: usize,
    /// Second-stage TLB entries.
    pub l2_entries: usize,
    /// Associativity of both stages.
    pub ways: usize,
    /// MSHRs for walks in flight.
    pub mshrs: usize,
    /// Latency of an L2-TLB hit.
    pub l2_latency: u32,
    /// Latency per page-walk memory access that misses walk caches.
    pub walk_latency: u32,
}

/// Branch-predictor choice (Table 5 studies BiMode_l and TAGE-SC-L; we
/// implement bimode, a large bimode, and a TAGE-lite).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BpChoice {
    /// Baseline bi-mode predictor.
    BiMode,
    /// Large bi-mode (4x tables) — paper Table 5 "BiMode_l".
    BiModeLarge,
    /// TAGE-like tagged geometric-history predictor — stands in for
    /// TAGE-SC-L.
    TageLite,
}

/// Stride prefetcher parameters (A64FX L1D has an 8-degree one).
#[derive(Debug, Clone, Copy)]
pub struct PrefetchParams {
    pub enabled: bool,
    /// Number of lines fetched ahead on a detected stride.
    pub degree: u32,
}

/// Full simulated-processor configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub name: &'static str,
    // ---- core ----
    /// Instructions fetched per cycle.
    pub fetch_width: u32,
    /// Out-of-order issue width.
    pub issue_width: u32,
    /// In-order commit width.
    pub commit_width: u32,
    /// Issue-queue entries.
    pub iq_entries: usize,
    /// Reorder-buffer entries.
    pub rob_entries: usize,
    /// Load-queue entries.
    pub lq_entries: usize,
    /// Store-queue entries.
    pub sq_entries: usize,
    /// Functional-unit counts indexed by `FuClass as usize` (None excluded).
    pub fu_counts: [u32; 8],
    /// Frontend redirect penalty after a resolved misprediction (cycles).
    pub redirect_penalty: u32,
    /// Pipeline depth from fetch to dispatch (decode/rename stages).
    pub frontend_depth: u32,
    // ---- memory system ----
    pub l1i: CacheParams,
    pub l1d: CacheParams,
    pub l2: CacheParams,
    /// Main-memory access latency (cycles).
    pub mem_latency: u32,
    pub itlb: TlbParams,
    pub dtlb: TlbParams,
    pub l1d_prefetch: PrefetchParams,
    // ---- branch prediction ----
    pub bp: BpChoice,
    /// Branch-target-buffer entries.
    pub btb_entries: usize,
    /// Return-address-stack entries.
    pub ras_entries: usize,
}

impl SimConfig {
    /// The paper's "Default O3CPU" column of Table 2: 3-wide fetch, 8-wide
    /// issue/commit, bi-mode, 32-entry IQ, 40-entry ROB, 16-entry LQ/SQ,
    /// 48KB L1I, 32KB L1D (5 cycles), 1MB L2 (29 cycles).
    pub fn default_o3() -> Self {
        SimConfig {
            name: "default_o3",
            fetch_width: 3,
            issue_width: 8,
            commit_width: 8,
            iq_entries: 32,
            rob_entries: 40,
            lq_entries: 16,
            sq_entries: 16,
            // IntAlu, IntMulDiv, FpAlu, FpMulDiv, Simd, LoadPort, StorePort, Branch
            fu_counts: [4, 1, 2, 1, 2, 2, 1, 1],
            redirect_penalty: 5,
            frontend_depth: 4,
            l1i: CacheParams { size: 48 << 10, ways: 3, line: 64, mshrs: 4, hit_latency: 1 },
            l1d: CacheParams { size: 32 << 10, ways: 2, line: 64, mshrs: 16, hit_latency: 5 },
            l2: CacheParams { size: 1 << 20, ways: 16, line: 64, mshrs: 32, hit_latency: 29 },
            mem_latency: 140,
            itlb: TlbParams {
                l1_entries: 48,
                l2_entries: 128,
                ways: 8,
                mshrs: 6,
                l2_latency: 8,
                walk_latency: 40,
            },
            dtlb: TlbParams {
                l1_entries: 48,
                l2_entries: 128,
                ways: 8,
                mshrs: 6,
                l2_latency: 8,
                walk_latency: 40,
            },
            l1d_prefetch: PrefetchParams { enabled: false, degree: 0 },
            bp: BpChoice::BiMode,
            btb_entries: 4096,
            ras_entries: 16,
        }
    }

    /// The paper's A64FX-like column of Table 2: 8-wide fetch, 4-wide
    /// issue/commit, 48-entry IQ, 128-entry ROB, 40/24 LQ/SQ, 64KB L1s
    /// (8-cycle L1D), 8MB L2 (111 cycles), 8-degree stride prefetcher.
    pub fn a64fx() -> Self {
        SimConfig {
            name: "a64fx",
            fetch_width: 8,
            issue_width: 4,
            commit_width: 4,
            iq_entries: 48,
            rob_entries: 128,
            lq_entries: 40,
            sq_entries: 24,
            fu_counts: [2, 1, 2, 2, 2, 2, 2, 1],
            redirect_penalty: 7,
            frontend_depth: 5,
            l1i: CacheParams { size: 64 << 10, ways: 4, line: 256, mshrs: 8, hit_latency: 2 },
            l1d: CacheParams { size: 64 << 10, ways: 4, line: 256, mshrs: 21, hit_latency: 8 },
            l2: CacheParams { size: 8 << 20, ways: 16, line: 256, mshrs: 64, hit_latency: 111 },
            mem_latency: 220,
            itlb: TlbParams {
                l1_entries: 32,
                l2_entries: 128,
                ways: 4,
                mshrs: 6,
                l2_latency: 10,
                walk_latency: 60,
            },
            dtlb: TlbParams {
                l1_entries: 32,
                l2_entries: 128,
                ways: 4,
                mshrs: 6,
                l2_latency: 10,
                walk_latency: 60,
            },
            l1d_prefetch: PrefetchParams { enabled: true, degree: 8 },
            bp: BpChoice::BiMode,
            btb_entries: 4096,
            ras_entries: 32,
        }
    }

    /// Maximum number of context instructions a processor of this size can
    /// hold: frontend buffer + ROB + SQ (paper: 110 for the default O3CPU).
    pub fn max_context(&self) -> usize {
        self.rob_entries + self.sq_entries + (self.fetch_width * self.frontend_depth) as usize
    }

    /// Latency for an access satisfied at `level` (1 = L1, 2 = L2, 3 = mem)
    /// for the given L1 cache.
    pub fn level_latency(&self, l1: &CacheParams, level: u8) -> u32 {
        match level {
            1 => l1.hit_latency,
            2 => l1.hit_latency + self.l2.hit_latency,
            _ => l1.hit_latency + self.l2.hit_latency + self.mem_latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        for cfg in [SimConfig::default_o3(), SimConfig::a64fx()] {
            assert!(cfg.rob_entries >= cfg.iq_entries);
            assert!(cfg.l2.size > cfg.l1d.size);
            assert!(cfg.l1d.sets() > 0 && cfg.l1i.sets() > 0 && cfg.l2.sets() > 0);
            assert!(cfg.max_context() > cfg.rob_entries);
        }
    }

    #[test]
    fn o3_matches_paper_table2() {
        let c = SimConfig::default_o3();
        assert_eq!(c.fetch_width, 3);
        assert_eq!(c.rob_entries, 40);
        assert_eq!(c.iq_entries, 32);
        assert_eq!(c.lq_entries, 16);
        assert_eq!(c.sq_entries, 16);
        assert_eq!(c.l1d.hit_latency, 5);
        assert_eq!(c.l2.hit_latency, 29);
    }

    #[test]
    fn level_latency_monotonic() {
        let c = SimConfig::default_o3();
        let l1 = c.l1d.clone();
        assert!(c.level_latency(&l1, 1) < c.level_latency(&l1, 2));
        assert!(c.level_latency(&l1, 2) < c.level_latency(&l1, 3));
    }
}
