//! Synthetic workload generation: the SPEC CPU 2017 substitute.
//!
//! A [`Workload`] is a phase schedule over [`Personality`]s; each phase owns
//! a deterministically built static [`Program`] and the stream switches
//! programs at phase boundaries, producing the phased CPI behaviour the
//! paper's Figure 6 studies.

pub mod builder;
pub mod exec;
pub mod program;
pub mod rng;
pub mod suite;

pub use builder::{build_program, Personality};
pub use exec::Executor;
pub use program::Program;
pub use suite::{find, suite, training_set, Benchmark, Category};

use crate::isa::Inst;

/// A runnable workload: one or more phases, cycled indefinitely.
pub struct Workload {
    phases: Vec<(u64, Program)>,
    input_seed: u64,
}

impl Workload {
    /// Build phase programs. `base_seed` fixes the static structure (the
    /// "binary"); `input_seed` varies the dynamic behaviour (the "input").
    pub fn new(phases: Vec<(u64, Personality)>, base_seed: u64, input_seed: u64) -> Self {
        let phases = phases
            .into_iter()
            .enumerate()
            .map(|(i, (len, p))| (len, build_program(&p, base_seed.wrapping_add(i as u64 * 7919))))
            .collect();
        Workload { phases, input_seed }
    }

    /// Iterate dynamic instructions indefinitely.
    pub fn stream(&self) -> WorkloadStream<'_> {
        WorkloadStream {
            wl: self,
            phase: 0,
            exec: Executor::new(&self.phases[0].1, self.input_seed),
            in_phase: 0,
        }
    }
}

/// Iterator over a workload's dynamic instruction stream.
pub struct WorkloadStream<'w> {
    wl: &'w Workload,
    phase: usize,
    exec: Executor<'w>,
    in_phase: u64,
}

impl<'w> Iterator for WorkloadStream<'w> {
    type Item = Inst;

    fn next(&mut self) -> Option<Inst> {
        let (len, _) = self.wl.phases[self.phase];
        if self.in_phase >= len {
            // Phase boundary: move to the next phase's program. Executor
            // seed advances so replays of the same phase differ.
            self.phase = (self.phase + 1) % self.wl.phases.len();
            let seed = self.wl.input_seed.wrapping_add(self.exec.emitted());
            self.exec = Executor::new(&self.wl.phases[self.phase].1, seed);
            self.in_phase = 0;
        }
        self.in_phase += 1;
        self.exec.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_switch_programs() {
        let a = Personality { load_frac: 0.0, store_frac: 0.0, ..Default::default() };
        let b = Personality { load_frac: 0.6, store_frac: 0.2, ..Default::default() };
        let wl = Workload::new(vec![(1000, a), (1000, b)], 1, 2);
        let insts: Vec<Inst> = wl.stream().take(2000).collect();
        let mem_first = insts[..1000].iter().filter(|i| i.op.is_mem()).count();
        let mem_second = insts[1000..].iter().filter(|i| i.op.is_mem()).count();
        assert!(mem_second > mem_first + 100, "first={mem_first} second={mem_second}");
    }

    #[test]
    fn stream_cycles_after_all_phases() {
        let wl = Workload::new(vec![(500, Personality::default())], 3, 4);
        let insts: Vec<Inst> = wl.stream().take(5000).collect();
        assert_eq!(insts.len(), 5000);
    }
}
