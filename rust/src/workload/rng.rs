//! Deterministic RNG for workload synthesis.
//!
//! Everything in the workload layer must be bit-reproducible across runs and
//! platforms (training data, validation traces, and benchmark inputs are all
//! derived from it), so we carry our own small PRNG rather than depending on
//! an external crate whose stream might change.

/// SplitMix64: tiny, fast, well-distributed. Used both directly and to seed
/// derived streams.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping; bias is negligible for the
        // bounds used here (all << 2^32).
        ((self.next_u64() >> 32).wrapping_mul(bound)) >> 32
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Geometric-ish positive integer with the given mean (>= 1).
    pub fn geometric(&mut self, mean: f64) -> u64 {
        let mean = mean.max(1.0);
        let p = 1.0 / mean;
        let u = self.f64().max(1e-12);
        ((u.ln() / (1.0 - p).ln()).floor() as u64).saturating_add(1)
    }

    /// Derive an independent stream (e.g. per-benchmark from a suite seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn geometric_mean_roughly_right() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let sum: u64 = (0..n).map(|_| r.geometric(6.0)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 6.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
