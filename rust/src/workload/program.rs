//! Static program representation for synthetic workloads.
//!
//! A [`Program`] is a small CFG of basic blocks with fixed PCs, fixed
//! register assignments, and parameterized *behaviours* (memory access
//! patterns, branch outcome processes). Functional execution of a program
//! (see [`super::exec`]) yields the dynamic instruction stream that the DES
//! timestamps. Keeping the static side fixed is what gives the stream the
//! locality structure real programs have: recurring PCs, loop branches,
//! stable register dependence chains — the properties branch predictors and
//! caches key on.

use crate::isa::{Inst, OpClass, RegId, MAX_DST_REGS, MAX_SRC_REGS, REG_NONE};

/// How a static load/store generates its effective addresses over time.
#[derive(Debug, Clone)]
pub enum MemPattern {
    /// Sequential streaming through a region: `base + (k * stride) % span`.
    Stride { base: u64, stride: u64, span: u64 },
    /// Dependent pointer chase through a region (random successor chain).
    Chase { base: u64, span: u64 },
    /// Uniform random access within a region.
    Rand { base: u64, span: u64 },
    /// Stack-relative access (small hot region).
    Stack { offset: u64 },
}

/// Branch outcome process for a block terminator.
#[derive(Debug, Clone)]
pub enum BranchBehavior {
    /// Loop back-edge: taken `iters-1` times, then falls through.
    Loop { iters: u64 },
    /// Taken with probability `p` (data-dependent, hard for predictors
    /// when p is near 0.5).
    Bernoulli { p: f64 },
    /// Deterministic repeating pattern of outcomes (bit i of `pattern`,
    /// period `period` <= 64). Predictable by history-based predictors
    /// (TAGE) but not by simple bimodal ones.
    Pattern { pattern: u64, period: u32 },
    /// Always taken.
    AlwaysTaken,
}

/// Block terminator.
#[derive(Debug, Clone)]
pub enum Terminator {
    /// Fall through to the next block in the function.
    FallThrough,
    /// Conditional branch: `taken` -> `target` block, else next block.
    CondBranch { target: usize, behavior: BranchBehavior },
    /// Unconditional jump to a block.
    Jump { target: usize },
    /// Indirect branch selecting among target blocks (weights uniform).
    Indirect { targets: Vec<usize> },
    /// Call a function (returns to the next block).
    Call { func: usize },
    /// Return from the current function.
    Ret,
}

/// A static (non-terminator) instruction inside a block.
#[derive(Debug, Clone)]
pub struct StaticInst {
    pub op: OpClass,
    pub srcs: [RegId; MAX_SRC_REGS],
    pub dsts: [RegId; MAX_DST_REGS],
    /// Memory behaviour for loads/stores; `None` otherwise.
    pub mem: Option<MemPattern>,
    /// Access size in bytes for loads/stores.
    pub mem_size: u8,
}

impl StaticInst {
    /// A plain ALU op with no operands (placeholder / nop-like).
    pub fn simple(op: OpClass) -> Self {
        StaticInst {
            op,
            srcs: [REG_NONE; MAX_SRC_REGS],
            dsts: [REG_NONE; MAX_DST_REGS],
            mem: None,
            mem_size: 0,
        }
    }

    /// Materialize a dynamic instance at a PC with a resolved address.
    pub fn instantiate(&self, pc: u64) -> Inst {
        Inst {
            pc,
            op: self.op,
            srcs: self.srcs,
            dsts: self.dsts,
            mem_addr: 0,
            mem_size: self.mem_size,
            target: 0,
            taken: false,
        }
    }
}

/// A basic block: straight-line instructions plus a terminator.
#[derive(Debug, Clone)]
pub struct Block {
    /// PC of the first instruction (instructions are 4 bytes each).
    pub pc: u64,
    pub insts: Vec<StaticInst>,
    pub term: Terminator,
}

impl Block {
    /// PC of the terminator instruction.
    pub fn term_pc(&self) -> u64 {
        self.pc + 4 * self.insts.len() as u64
    }

    /// PC just past this block (start of the fall-through successor).
    pub fn end_pc(&self) -> u64 {
        self.term_pc() + 4
    }
}

/// A function: a contiguous range of blocks. Execution enters at
/// `blocks[0]` and leaves via `Ret`.
#[derive(Debug, Clone)]
pub struct Function {
    pub blocks: Vec<Block>,
}

/// A whole synthetic program: functions plus an entry.
#[derive(Debug, Clone)]
pub struct Program {
    pub funcs: Vec<Function>,
    /// Entry function index.
    pub entry: usize,
}

impl Program {
    /// Total static instruction count (including terminators).
    pub fn static_size(&self) -> usize {
        self.funcs
            .iter()
            .flat_map(|f| f.blocks.iter())
            .map(|b| b.insts.len() + 1)
            .sum()
    }

    /// Sanity-check CFG target indices; panics on malformed programs.
    /// Used by tests and the builder.
    pub fn validate(&self) {
        assert!(self.entry < self.funcs.len(), "entry out of range");
        for (fi, f) in self.funcs.iter().enumerate() {
            assert!(!f.blocks.is_empty(), "function {fi} empty");
            for (bi, b) in f.blocks.iter().enumerate() {
                match &b.term {
                    Terminator::FallThrough => {
                        assert!(bi + 1 < f.blocks.len(), "fallthrough off the end of fn {fi}")
                    }
                    Terminator::CondBranch { target, .. } => {
                        assert!(*target < f.blocks.len());
                        assert!(bi + 1 < f.blocks.len(), "cond branch at end of fn {fi}");
                    }
                    Terminator::Jump { target } => assert!(*target < f.blocks.len()),
                    Terminator::Indirect { targets } => {
                        assert!(!targets.is_empty());
                        for t in targets {
                            assert!(*t < f.blocks.len());
                        }
                    }
                    Terminator::Call { func } => {
                        assert!(*func < self.funcs.len());
                        assert!(bi + 1 < f.blocks.len(), "call at end of fn {fi}");
                    }
                    Terminator::Ret => {}
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_program() -> Program {
        let b0 = Block {
            pc: 0x1000,
            insts: vec![StaticInst::simple(OpClass::IntAlu)],
            term: Terminator::CondBranch {
                target: 0,
                behavior: BranchBehavior::Loop { iters: 3 },
            },
        };
        let b1 = Block { pc: 0x2000, insts: vec![], term: Terminator::Ret };
        Program { funcs: vec![Function { blocks: vec![b0, b1] }], entry: 0 }
    }

    #[test]
    fn validate_accepts_wellformed() {
        tiny_program().validate();
    }

    #[test]
    #[should_panic]
    fn validate_rejects_bad_target() {
        let mut p = tiny_program();
        p.funcs[0].blocks[0].term = Terminator::Jump { target: 99 };
        p.validate();
    }

    #[test]
    fn pc_layout() {
        let p = tiny_program();
        let b = &p.funcs[0].blocks[0];
        assert_eq!(b.term_pc(), 0x1004);
        assert_eq!(b.end_pc(), 0x1008);
        assert_eq!(p.static_size(), 3);
    }
}
