//! The synthetic benchmark suite: 25 named workloads standing in for SPEC
//! CPU 2017 (paper Table 3).
//!
//! Each entry gets a personality tuned to the published character of its
//! namesake (memory-bound, branchy, fp-streaming, phased, ...). The split
//! into a 4-benchmark ML set and a 21-benchmark simulation-only set mirrors
//! the paper; simulation runs additionally use a different input seed
//! ("reference workload") than dataset generation ("test workload").

use super::builder::Personality;
use super::Workload;

/// Benchmark category (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Integer benchmark.
    Int,
    /// Floating-point benchmark.
    Fp,
}

/// A named benchmark in the suite.
#[derive(Debug, Clone)]
pub struct Benchmark {
    pub name: &'static str,
    pub category: Category,
    /// Member of the 4-benchmark ML training set?
    pub training: bool,
    /// Phase schedule: (instructions, personality). Cycled when exhausted.
    pub phases: Vec<(u64, Personality)>,
    /// Base seed; combined with the input-set seed at build time.
    pub seed: u64,
}

impl Benchmark {
    /// Build the runnable workload for an input set. `input_seed` plays the
    /// role of SPEC's test vs. reference inputs: a different seed yields a
    /// different dynamic stream over the same static program structure.
    pub fn workload(&self, input_seed: u64) -> Workload {
        Workload::new(self.phases.clone(), self.seed, input_seed)
    }
}

fn p() -> Personality {
    Personality::default()
}

/// Integer, branchy, irregular (interpreter-like).
fn branchy(mispredict: f64, ws_kb: u64) -> Personality {
    Personality {
        fp_frac: 0.02,
        simd_frac: 0.0,
        load_frac: 0.28,
        store_frac: 0.12,
        stride_frac: 0.25,
        chase_frac: 0.45,
        hot_bytes: 24 << 10,
        warm_bytes: ws_kb << 10,
        cold_bytes: 16 << 20,
        hot_p: 0.55,
        warm_p: 0.35,
        block_len: 4.0,
        bernoulli_frac: 0.55,
        bernoulli_p: mispredict,
        indirect_frac: 0.08,
        call_frac: 0.12,
        ..p()
    }
}

/// Memory-latency-bound pointer chaser.
fn pointer_chaser(cold_mb: u64) -> Personality {
    Personality {
        fp_frac: 0.02,
        load_frac: 0.35,
        store_frac: 0.08,
        stride_frac: 0.1,
        chase_frac: 0.75,
        hot_bytes: 8 << 10,
        warm_bytes: 128 << 10,
        cold_bytes: cold_mb << 20,
        hot_p: 0.25,
        warm_p: 0.25,
        block_len: 5.0,
        bernoulli_frac: 0.4,
        bernoulli_p: 0.2,
        loop_iters: 6.0,
        ..p()
    }
}

/// FP streaming kernel (regular strides, long loops, wide blocks).
fn fp_stream(simd: f64, cold_mb: u64) -> Personality {
    Personality {
        fp_frac: 0.55,
        simd_frac: simd,
        mul_frac: 0.3,
        div_frac: 0.015,
        load_frac: 0.3,
        store_frac: 0.14,
        stride_frac: 0.9,
        chase_frac: 0.02,
        hot_bytes: 32 << 10,
        warm_bytes: 512 << 10,
        cold_bytes: cold_mb << 20,
        hot_p: 0.35,
        warm_p: 0.3,
        block_len: 12.0,
        bernoulli_frac: 0.08,
        bernoulli_p: 0.04,
        loop_iters: 64.0,
        indirect_frac: 0.01,
        call_frac: 0.04,
        ..p()
    }
}

/// Compute-bound integer (game tree search: predictable-ish branches,
/// small working set, lots of ALU).
fn int_compute(bern: f64) -> Personality {
    Personality {
        fp_frac: 0.03,
        load_frac: 0.2,
        store_frac: 0.08,
        stride_frac: 0.4,
        chase_frac: 0.25,
        hot_bytes: 48 << 10,
        warm_bytes: 256 << 10,
        cold_bytes: 4 << 20,
        hot_p: 0.7,
        warm_p: 0.25,
        block_len: 6.0,
        bernoulli_frac: 0.45,
        bernoulli_p: bern,
        call_frac: 0.15,
        loop_iters: 8.0,
        ..p()
    }
}

/// FP compute with mixed locality (multiphysics style).
fn fp_mixed(div: f64, cold_mb: u64) -> Personality {
    Personality {
        fp_frac: 0.45,
        simd_frac: 0.12,
        mul_frac: 0.3,
        div_frac: div,
        load_frac: 0.27,
        store_frac: 0.12,
        stride_frac: 0.6,
        chase_frac: 0.15,
        hot_bytes: 24 << 10,
        warm_bytes: 768 << 10,
        cold_bytes: cold_mb << 20,
        hot_p: 0.45,
        warm_p: 0.3,
        block_len: 9.0,
        bernoulli_frac: 0.2,
        bernoulli_p: 0.08,
        loop_iters: 24.0,
        ..p()
    }
}

fn phases1(len: u64, a: Personality) -> Vec<(u64, Personality)> {
    vec![(len, a)]
}

fn phases2(la: u64, a: Personality, lb: u64, b: Personality) -> Vec<(u64, Personality)> {
    vec![(la, a), (lb, b)]
}

/// Build the full 25-benchmark suite.
pub fn suite() -> Vec<Benchmark> {
    use Category::*;
    let mut v = Vec::new();
    let mut seed = 0xC0FFEE00u64;
    let mut add = |name: &'static str,
                   category: Category,
                   training: bool,
                   phases: Vec<(u64, Personality)>| {
        seed = seed.wrapping_add(0x9E37_79B9);
        v.push(Benchmark { name, category, training, phases, seed });
    };

    // ---- ML (training) set: Table 3 top row ----
    add("perlbench", Int, true, phases2(400_000, branchy(0.35, 512), 250_000, int_compute(0.3)));
    add("gcc", Int, true, phases2(300_000, branchy(0.3, 2048), 300_000, pointer_chaser(8)));
    add("bwaves", Fp, true, phases2(600_000, fp_stream(0.25, 64), 150_000, fp_mixed(0.02, 16)));
    add("namd", Fp, true, phases1(500_000, fp_mixed(0.01, 8)));

    // ---- Simulation-only set: Table 3 bottom rows ----
    add("mcf", Int, false, phases1(500_000, pointer_chaser(32)));
    add("omnetpp", Int, false, phases1(500_000, branchy(0.25, 4096)));
    add("xalancbmk", Int, false, phases2(200_000, branchy(0.4, 1024), 200_000, pointer_chaser(4)));
    add("x264", Int, false, phases2(350_000, fp_stream(0.5, 8), 200_000, int_compute(0.15)));
    add("deepsjeng", Int, false, phases1(500_000, int_compute(0.4)));
    add("leela", Int, false, phases1(500_000, int_compute(0.25)));
    add("exchange2", Int, false, phases1(500_000, int_compute(0.1)));
    add("xz", Int, false, phases2(300_000, branchy(0.2, 8192), 300_000, int_compute(0.35)));
    add("specrand_i", Int, false, phases2(150_000, int_compute(0.5), 150_000, branchy(0.5, 64)));
    add("cactuBSSN", Fp, false, phases2(400_000, fp_mixed(0.04, 32), 250_000, fp_stream(0.1, 32)));
    add("parest", Fp, false, phases1(500_000, fp_mixed(0.02, 16)));
    add("povray", Fp, false, phases1(500_000, fp_mixed(0.05, 2)));
    add("lbm", Fp, false, phases1(600_000, fp_stream(0.4, 128)));
    add("wrf", Fp, false, phases2(300_000, fp_mixed(0.03, 24), 300_000, fp_stream(0.2, 48)));
    add("blender", Fp, false, phases2(250_000, fp_mixed(0.06, 8), 250_000, branchy(0.3, 512)));
    add("cam4", Fp, false, phases2(200_000, fp_mixed(0.03, 16), 350_000, fp_stream(0.15, 96)));
    add("imagick", Fp, false, phases1(500_000, fp_stream(0.35, 4)));
    add("nab", Fp, false, phases1(500_000, fp_mixed(0.02, 4)));
    add("fotonik3d", Fp, false, phases1(600_000, fp_stream(0.3, 192)));
    add("roms", Fp, false, phases2(350_000, fp_stream(0.2, 64), 250_000, fp_mixed(0.02, 32)));
    add("specrand_f", Fp, false, phases2(150_000, fp_mixed(0.08, 1), 150_000, int_compute(0.5)));
    v
}

/// Look up a benchmark by name.
pub fn find(name: &str) -> Option<Benchmark> {
    suite().into_iter().find(|b| b.name == name)
}

/// Names of the training (ML-set) benchmarks.
pub fn training_set() -> Vec<&'static str> {
    suite().iter().filter(|b| b.training).map(|b| b.name).collect()
}

/// The extended 15-benchmark training set used by the §4.5 dataset-size
/// study: the 4 ML benchmarks plus the next 11 from the suite.
pub fn large_training_set() -> Vec<&'static str> {
    suite().iter().take(15).map(|b| b.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_25_benchmarks() {
        let s = suite();
        assert_eq!(s.len(), 25);
        assert_eq!(s.iter().filter(|b| b.training).count(), 4);
    }

    #[test]
    fn names_unique() {
        let s = suite();
        let names: std::collections::HashSet<_> = s.iter().map(|b| b.name).collect();
        assert_eq!(names.len(), s.len());
    }

    #[test]
    fn find_works() {
        assert!(find("mcf").is_some());
        assert!(find("perlbench").unwrap().training);
        assert!(find("nonexistent").is_none());
    }

    #[test]
    fn workloads_produce_instructions() {
        for b in suite().iter().take(6) {
            let wl = b.workload(0);
            let insts: Vec<_> = wl.stream().take(1000).collect();
            assert_eq!(insts.len(), 1000, "{} produced too few", b.name);
        }
    }

    #[test]
    fn input_seed_changes_stream() {
        let b = find("gcc").unwrap();
        let a: Vec<_> = b.workload(0).stream().take(2000).collect();
        let c: Vec<_> = b.workload(1).stream().take(2000).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn large_training_set_is_15() {
        assert_eq!(large_training_set().len(), 15);
    }
}
