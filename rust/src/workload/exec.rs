//! Functional executor: walks a static [`Program`] and emits the dynamic
//! instruction stream (PCs, resolved effective addresses, branch outcomes).
//!
//! This plays the role of gem5's functional front-end / QEMU in the paper's
//! workflow (§4.3): it is purely architectural — no timing — and is cheap
//! enough to run at trace-generation speed.

use std::collections::HashMap;

use super::builder::STACK_REGION;
use super::program::*;
use super::rng::Rng;
use crate::isa::{Inst, OpClass, REG_LR, REG_SP};

/// Per-static-load/store dynamic state (stride position or chase pointer).
#[derive(Debug, Clone, Copy, Default)]
struct MemState {
    counter: u64,
    chase_ptr: u64,
}

/// Per-terminator dynamic state (loop trip counters, pattern phase).
#[derive(Debug, Clone, Copy, Default)]
struct BranchState {
    counter: u64,
}

/// Functional execution engine. Iterate to obtain [`Inst`]s forever (the
/// program restarts at its entry upon returning from the outermost frame).
pub struct Executor<'p> {
    prog: &'p Program,
    rng: Rng,
    /// (function, block, next-instruction-index) frames; last = current.
    stack: Vec<Frame>,
    mem_state: HashMap<u64, MemState>,
    br_state: HashMap<u64, BranchState>,
    emitted: u64,
}

#[derive(Debug, Clone, Copy)]
struct Frame {
    func: usize,
    block: usize,
    inst: usize,
}

impl<'p> Executor<'p> {
    pub fn new(prog: &'p Program, seed: u64) -> Self {
        Executor {
            prog,
            rng: Rng::new(seed ^ 0x5EED_CAFE),
            stack: vec![Frame { func: prog.entry, block: 0, inst: 0 }],
            mem_state: HashMap::new(),
            br_state: HashMap::new(),
            emitted: 0,
        }
    }

    /// Total instructions emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    fn cur_block(&self) -> &'p Block {
        let f = self.stack.last().unwrap();
        &self.prog.funcs[f.func].blocks[f.block]
    }

    /// Resolve the effective address for a static memory instruction.
    fn resolve_addr(&mut self, pc: u64, pattern: &MemPattern) -> u64 {
        let st = self.mem_state.entry(pc).or_default();
        match pattern {
            MemPattern::Stride { base, stride, span } => {
                let addr = base + (st.counter * stride) % (*span).max(1);
                st.counter += 1;
                addr
            }
            MemPattern::Chase { base, span } => {
                if st.chase_ptr == 0 {
                    st.chase_ptr = *base;
                }
                let cur = st.chase_ptr;
                // Dependent successor: hash the current pointer. Aligned to
                // 8B; stays within [base, base+span).
                let mut h = cur.wrapping_mul(0x9E3779B97F4A7C15);
                h ^= h >> 29;
                st.chase_ptr = (base + h % (*span).max(8)) & !7;
                cur
            }
            MemPattern::Rand { base, span } => base + (self.rng.below((*span).max(8)) & !7),
            MemPattern::Stack { offset } => {
                let depth = self.stack.len() as u64;
                STACK_REGION - depth * 1024 + offset
            }
        }
    }

    /// Evaluate a branch behaviour at this dynamic occurrence.
    fn resolve_branch(&mut self, pc: u64, behavior: &BranchBehavior) -> bool {
        let st = self.br_state.entry(pc).or_default();
        let k = st.counter;
        st.counter += 1;
        match behavior {
            BranchBehavior::Loop { iters } => {
                // Taken (loop again) for iters-1 occurrences, then reset.
                if (k + 1) % iters.max(&1) == 0 {
                    false
                } else {
                    true
                }
            }
            BranchBehavior::Bernoulli { p } => self.rng.chance(*p),
            BranchBehavior::Pattern { pattern, period } => {
                (pattern >> (k % *period as u64)) & 1 == 1
            }
            BranchBehavior::AlwaysTaken => true,
        }
    }
}

impl<'p> Iterator for Executor<'p> {
    type Item = Inst;

    fn next(&mut self) -> Option<Inst> {
        let block = self.cur_block();
        let f = *self.stack.last().unwrap();

        // Straight-line portion of the block.
        if f.inst < block.insts.len() {
            let sinst = &block.insts[f.inst];
            let pc = block.pc + 4 * f.inst as u64;
            let mut inst = sinst.instantiate(pc);
            if let Some(pat) = &sinst.mem {
                let pat = pat.clone();
                inst.mem_addr = self.resolve_addr(pc, &pat);
            }
            self.stack.last_mut().unwrap().inst += 1;
            self.emitted += 1;
            return Some(inst);
        }

        // Terminator.
        let pc = block.term_pc();
        let fnblocks = &self.prog.funcs[f.func].blocks;
        let mut inst = Inst { pc, taken: true, ..Default::default() };
        match &block.term {
            Terminator::FallThrough => {
                // Layout-only: emit a cheap filler op and advance.
                inst.op = OpClass::IntAlu;
                inst.taken = false;
                self.goto(f.func, f.block + 1);
            }
            Terminator::CondBranch { target, behavior } => {
                inst.op = OpClass::CondBranch;
                let behavior = behavior.clone();
                let taken = self.resolve_branch(pc, &behavior);
                inst.taken = taken;
                let next = if taken { *target } else { f.block + 1 };
                inst.target = fnblocks[next].pc;
                self.goto(f.func, next);
            }
            Terminator::Jump { target } => {
                inst.op = OpClass::Jump;
                inst.target = fnblocks[*target].pc;
                let t = *target;
                self.goto(f.func, t);
            }
            Terminator::Indirect { targets } => {
                inst.op = OpClass::IndirectBranch;
                inst.srcs[0] = 9; // target register
                let t = targets[self.rng.index(targets.len())];
                inst.target = fnblocks[t].pc;
                self.goto(f.func, t);
            }
            Terminator::Call { func } => {
                inst.op = OpClass::Call;
                inst.dsts[0] = REG_LR;
                inst.srcs[0] = REG_SP;
                let callee = *func;
                inst.target = self.prog.funcs[callee].blocks[0].pc;
                // Return continues at the caller's next block.
                self.stack.last_mut().unwrap().block = f.block + 1;
                self.stack.last_mut().unwrap().inst = 0;
                self.stack.push(Frame { func: callee, block: 0, inst: 0 });
            }
            Terminator::Ret => {
                inst.op = OpClass::Ret;
                inst.srcs[0] = REG_LR;
                self.stack.pop();
                if self.stack.is_empty() {
                    // Outermost return: restart the program (steady-state
                    // benchmark loop).
                    self.stack.push(Frame { func: self.prog.entry, block: 0, inst: 0 });
                }
                let nf = self.stack.last().unwrap();
                inst.target = self.prog.funcs[nf.func].blocks[nf.block].pc;
            }
        }
        self.emitted += 1;
        Some(inst)
    }
}

impl<'p> Executor<'p> {
    fn goto(&mut self, func: usize, block: usize) {
        let top = self.stack.last_mut().unwrap();
        top.func = func;
        top.block = block;
        top.inst = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::builder::{build_program, Personality};

    #[test]
    fn runs_forever_and_deterministic() {
        let prog = build_program(&Personality::default(), 1);
        let a: Vec<Inst> = Executor::new(&prog, 2).take(5000).collect();
        let b: Vec<Inst> = Executor::new(&prog, 2).take(5000).collect();
        assert_eq!(a.len(), 5000);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seed_different_stream() {
        let prog = build_program(&Personality::default(), 1);
        let a: Vec<Inst> = Executor::new(&prog, 2).take(2000).collect();
        let b: Vec<Inst> = Executor::new(&prog, 3).take(2000).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn pcs_recur() {
        // Loops must revisit PCs — that's what history context keys on.
        let prog = build_program(&Personality::default(), 4);
        let insts: Vec<Inst> = Executor::new(&prog, 4).take(20_000).collect();
        let unique: std::collections::HashSet<u64> = insts.iter().map(|i| i.pc).collect();
        assert!(unique.len() < insts.len() / 4, "unique={} total={}", unique.len(), insts.len());
    }

    #[test]
    fn memory_ops_have_addresses() {
        let prog = build_program(&Personality::default(), 9);
        for inst in Executor::new(&prog, 9).take(20_000) {
            if inst.op.is_mem() {
                assert!(inst.mem_addr != 0, "mem op without address: {inst:?}");
                assert!(inst.mem_size > 0);
            }
            if inst.op.is_control() {
                assert!(inst.target != 0 || !inst.taken);
            }
        }
    }

    #[test]
    fn loop_behavior_taken_ratio() {
        // A Loop{iters: 5} back-edge should be taken 4 of every 5 times.
        let b0 = Block {
            pc: 0x1000,
            insts: vec![],
            term: Terminator::CondBranch {
                target: 0,
                behavior: BranchBehavior::Loop { iters: 5 },
            },
        };
        let b1 = Block { pc: 0x2000, insts: vec![], term: Terminator::Ret };
        let prog = Program { funcs: vec![Function { blocks: vec![b0, b1] }], entry: 0 };
        prog.validate();
        let insts: Vec<Inst> =
            Executor::new(&prog, 0).take(1000).filter(|i| i.op == OpClass::CondBranch).collect();
        let taken = insts.iter().filter(|i| i.taken).count();
        let ratio = taken as f64 / insts.len() as f64;
        assert!((ratio - 0.8).abs() < 0.02, "ratio={ratio}");
    }

    #[test]
    fn call_stack_bounded() {
        let p = Personality { call_frac: 0.3, num_funcs: 6, ..Default::default() };
        let prog = build_program(&p, 11);
        let mut ex = Executor::new(&prog, 11);
        for _ in 0..50_000 {
            ex.next();
            assert!(ex.stack.len() <= p.num_funcs + 1, "stack grew unbounded");
        }
    }
}
