//! Build a random static [`Program`] from a workload [`Personality`].

use super::program::*;
use super::rng::Rng;
use crate::isa::{OpClass, RegId, MAX_DST_REGS, MAX_SRC_REGS, REG_NONE};

/// Knobs describing the *character* of a synthetic benchmark. Each SPEC-like
/// workload in [`super::suite`] is one of these. The values are chosen per
/// benchmark to mimic the published behaviour classes (memory-bound,
/// branchy, fp-heavy, phased, ...) rather than any proprietary trace.
#[derive(Debug, Clone)]
pub struct Personality {
    /// Fraction of non-memory, non-branch ops that are FP.
    pub fp_frac: f64,
    /// Fraction of non-memory, non-branch ops that are SIMD.
    pub simd_frac: f64,
    /// Among int/fp compute ops, fraction that are multiplies.
    pub mul_frac: f64,
    /// Among int/fp compute ops, fraction that are divides/sqrts.
    pub div_frac: f64,
    /// Fraction of instructions that are loads.
    pub load_frac: f64,
    /// Fraction of instructions that are stores.
    pub store_frac: f64,
    /// Fraction of memory ops that stream with a regular stride.
    pub stride_frac: f64,
    /// Fraction of memory ops that pointer-chase (dependent, cache-hostile).
    pub chase_frac: f64,
    /// Remaining memory ops are uniform-random in their region.
    /// Region sizes (bytes): hot (L1-resident), warm (L2-resident), cold.
    pub hot_bytes: u64,
    pub warm_bytes: u64,
    pub cold_bytes: u64,
    /// Probability a memory op targets [hot, warm] (else cold).
    pub hot_p: f64,
    pub warm_p: f64,
    /// Mean basic-block length (instructions before the terminator).
    pub block_len: f64,
    /// Probability a conditional branch is data-dependent (Bernoulli) as
    /// opposed to a loop back-edge or a repeating pattern.
    pub bernoulli_frac: f64,
    /// Taken-probability used for data-dependent branches (0.5 = hardest).
    pub bernoulli_p: f64,
    /// Mean loop trip count for back-edges.
    pub loop_iters: f64,
    /// Fraction of block terminators that are indirect branches.
    pub indirect_frac: f64,
    /// Fraction of block terminators that are calls.
    pub call_frac: f64,
    /// Per-instruction probability of a memory barrier.
    pub barrier_frac: f64,
    /// Per-instruction probability of a serializing op.
    pub serialize_frac: f64,
    /// Number of functions to generate.
    pub num_funcs: usize,
    /// Blocks per function (mean).
    pub blocks_per_func: f64,
}

impl Default for Personality {
    fn default() -> Self {
        Personality {
            fp_frac: 0.2,
            simd_frac: 0.1,
            mul_frac: 0.15,
            div_frac: 0.02,
            load_frac: 0.25,
            store_frac: 0.10,
            stride_frac: 0.5,
            chase_frac: 0.2,
            hot_bytes: 16 << 10,
            warm_bytes: 256 << 10,
            cold_bytes: 64 << 20,
            hot_p: 0.6,
            warm_p: 0.3,
            block_len: 6.0,
            bernoulli_frac: 0.3,
            bernoulli_p: 0.1,
            loop_iters: 12.0,
            indirect_frac: 0.04,
            call_frac: 0.08,
            barrier_frac: 0.002,
            serialize_frac: 0.0005,
            num_funcs: 8,
            blocks_per_func: 10.0,
        }
    }
}

/// Data-region base addresses. Code lives at CODE_BASE; each region is
/// page-aligned and disjoint so TLB behaviour differs per region.
const CODE_BASE: u64 = 0x0040_0000;
const STACK_BASE: u64 = 0x7FFF_0000;
const HOT_BASE: u64 = 0x1000_0000;
const WARM_BASE: u64 = 0x2000_0000;
const COLD_BASE: u64 = 0x4000_0000;

/// Deterministically build a program from a personality and seed.
pub fn build_program(p: &Personality, seed: u64) -> Program {
    let mut rng = Rng::new(seed);
    let mut funcs = Vec::with_capacity(p.num_funcs);
    let mut next_pc = CODE_BASE;

    let nfuncs = p.num_funcs.max(2);
    // Function 0 is the driver: it calls every other function inside small
    // loops, like a benchmark's main loop. This guarantees each program
    // iteration exercises the whole static footprint instead of whatever
    // short path a random entry function happens to take to its Ret.
    {
        let mut blocks = Vec::new();
        for callee in 1..nfuncs {
            let call_block_idx = blocks.len();
            let mut insts = Vec::new();
            for _ in 0..rng.geometric(p.block_len).clamp(2, 16) {
                insts.push(gen_inst(p, &mut rng));
            }
            blocks.push(Block {
                pc: 0,
                insts,
                term: Terminator::Call { func: callee },
            });
            // Re-invoke the callee a few times before moving on.
            blocks.push(Block {
                pc: 0,
                insts: vec![gen_inst(p, &mut rng), gen_inst(p, &mut rng)],
                term: Terminator::CondBranch {
                    target: call_block_idx,
                    behavior: BranchBehavior::Loop {
                        iters: rng.geometric(3.0).clamp(2, 8),
                    },
                },
            });
        }
        blocks.push(Block {
            pc: 0,
            insts: vec![gen_inst(p, &mut rng)],
            term: Terminator::Ret,
        });
        // Assign PCs now that the block list is final.
        for b in &mut blocks {
            b.pc = next_pc;
            next_pc = b.end_pc();
        }
        funcs.push(Function { blocks });
        next_pc = (next_pc + 0xFFF) & !0xFFF;
    }

    for fi in 1..nfuncs {
        let nblocks = rng.geometric(p.blocks_per_func).clamp(3, 64) as usize;
        let mut blocks = Vec::with_capacity(nblocks);
        for bi in 0..nblocks {
            let len = rng.geometric(p.block_len).clamp(2, 32) as usize;
            let mut insts = Vec::with_capacity(len);
            for _ in 0..len {
                insts.push(gen_inst(p, &mut rng));
            }
            // Hot loops in real code touch memory; make sure a block that
            // may become a loop body is not a pure-ALU spin (which would
            // starve the cache/TLB models when the loop dominates a phase).
            let mem_weight = p.load_frac + p.store_frac;
            if mem_weight > 0.1 && !insts.iter().any(|i| i.mem.is_some()) {
                let slot = rng.index(insts.len());
                insts[slot] = gen_mem_inst(p, &mut rng);
            }
            let term = gen_term(p, &mut rng, bi, nblocks, fi, nfuncs);
            let block = Block { pc: next_pc, insts, term };
            next_pc = block.end_pc();
            // Leave a small gap between blocks sometimes so fetch crosses
            // cache lines irregularly.
            if rng.chance(0.2) {
                next_pc += 4 * rng.below(4);
            }
            blocks.push(block);
        }
        // Function must end with Ret; also terminators that need a
        // fall-through successor cannot sit in the last block.
        fix_last_block(&mut blocks);
        funcs.push(Function { blocks });
        next_pc = (next_pc + 0xFFF) & !0xFFF; // next function page-aligned
    }

    let prog = Program { funcs, entry: 0 };
    prog.validate();
    prog
}

/// Pick registers with a bias toward low indices so chains form.
fn pick_reg(rng: &mut Rng, simd: bool) -> RegId {
    let base: RegId = if simd { 32 } else { 0 };
    // Zipf-ish: square the uniform draw to bias toward low registers,
    // creating realistic read-after-write dependence density.
    let u = rng.f64();
    base + ((u * u * 28.0) as RegId).min(27)
}

/// Generate a load/store with a personality-appropriate access pattern.
fn gen_mem_inst(p: &Personality, rng: &mut Rng) -> StaticInst {
    let is_load = rng.f64() < p.load_frac / (p.load_frac + p.store_frac).max(1e-9);
    let op = if is_load { OpClass::Load } else { OpClass::Store };
    let mem = Some(gen_mem_pattern(p, rng));
    let mem_size = [1u8, 2, 4, 8, 8, 8, 16][rng.index(7)];
    let mut srcs = [REG_NONE; MAX_SRC_REGS];
    let mut dsts = [REG_NONE; MAX_DST_REGS];
    srcs[0] = pick_reg(rng, false); // address base
    let data_is_fp = rng.chance(p.fp_frac);
    if is_load {
        dsts[0] = pick_reg(rng, data_is_fp);
    } else {
        srcs[1] = pick_reg(rng, data_is_fp); // store data
    }
    StaticInst { op, srcs, dsts, mem, mem_size }
}

fn gen_inst(p: &Personality, rng: &mut Rng) -> StaticInst {
    let r = rng.f64();
    // Memory ops.
    if r < p.load_frac + p.store_frac {
        return gen_mem_inst(p, rng);
    }
    // Barriers / serializing ops.
    if rng.chance(p.barrier_frac) {
        return StaticInst::simple(OpClass::MemBarrier);
    }
    if rng.chance(p.serialize_frac) {
        return StaticInst::simple(OpClass::Serialize);
    }
    // Compute ops.
    let simd = rng.chance(p.simd_frac);
    let fp = !simd && rng.chance(p.fp_frac);
    let kind = rng.f64();
    let op = if simd {
        if kind < p.mul_frac { OpClass::SimdMult } else { OpClass::SimdAlu }
    } else if fp {
        if kind < p.div_frac {
            if rng.chance(0.3) { OpClass::FloatSqrt } else { OpClass::FloatDiv }
        } else if kind < p.div_frac + p.mul_frac {
            OpClass::FloatMult
        } else {
            OpClass::FloatAdd
        }
    } else if kind < p.div_frac {
        OpClass::IntDiv
    } else if kind < p.div_frac + p.mul_frac {
        OpClass::IntMult
    } else {
        OpClass::IntAlu
    };
    let reg_simd = simd || fp;
    let mut srcs = [REG_NONE; MAX_SRC_REGS];
    let mut dsts = [REG_NONE; MAX_DST_REGS];
    let nsrc = 1 + rng.index(if simd { 3 } else { 2 });
    for s in srcs.iter_mut().take(nsrc) {
        *s = pick_reg(rng, reg_simd);
    }
    dsts[0] = pick_reg(rng, reg_simd);
    if simd && rng.chance(0.1) {
        dsts[1] = pick_reg(rng, true); // wide ops writing a register pair
    }
    StaticInst { op, srcs, dsts, mem: None, mem_size: 0 }
}

fn gen_mem_pattern(p: &Personality, rng: &mut Rng) -> MemPattern {
    let region = rng.f64();
    let (base, span) = if region < p.hot_p {
        (HOT_BASE, p.hot_bytes)
    } else if region < p.hot_p + p.warm_p {
        (WARM_BASE, p.warm_bytes)
    } else {
        (COLD_BASE, p.cold_bytes)
    };
    // Per-static-instruction sub-region so distinct PCs touch distinct data.
    let sub = rng.below(4);
    let base = base + sub * (span / 4).max(64);
    let span = (span / 2).max(256);
    let style = rng.f64();
    if rng.chance(0.08) {
        return MemPattern::Stack { offset: rng.below(512) & !7 };
    }
    if style < p.stride_frac {
        let stride = [8u64, 8, 16, 64, 64, 128, 256][rng.index(7)];
        MemPattern::Stride { base, stride, span }
    } else if style < p.stride_frac + p.chase_frac {
        MemPattern::Chase { base, span }
    } else {
        MemPattern::Rand { base, span }
    }
}

fn gen_term(
    p: &Personality,
    rng: &mut Rng,
    bi: usize,
    nblocks: usize,
    fi: usize,
    nfuncs: usize,
) -> Terminator {
    let not_last = bi + 1 < nblocks;
    let r = rng.f64();
    if r < p.call_frac && not_last && nfuncs > 1 {
        // Call a strictly-later function to keep the call graph acyclic
        // (bounded stack depth without needing recursion limits).
        if fi + 1 < nfuncs {
            let callee = fi + 1 + rng.index(nfuncs - fi - 1);
            return Terminator::Call { func: callee };
        }
    }
    // Forward progress guarantee: unconditional control flow (jumps,
    // indirect branches) only targets *later* blocks, and backward
    // conditional edges use Loop behaviour (which always eventually falls
    // through). This keeps the CFG free of inescapable cycles while still
    // producing real loop nests.
    if r < p.call_frac + p.indirect_frac && bi + 2 < nblocks {
        let fwd = nblocks - bi - 1;
        let ntargets = (2 + rng.index(4)).min(fwd);
        let targets = (0..ntargets).map(|_| bi + 1 + rng.index(fwd)).collect();
        return Terminator::Indirect { targets };
    }
    if not_last && rng.chance(0.55) {
        if bi > 0 && rng.chance(0.6) {
            // Loop back-edge: always exits after `iters` trips.
            let target = rng.index(bi);
            let behavior =
                BranchBehavior::Loop { iters: rng.geometric(p.loop_iters).clamp(2, 64) };
            return Terminator::CondBranch { target, behavior };
        }
        // Forward skip: both outcomes make progress, so any behaviour is
        // safe — including hard-to-predict Bernoulli branches.
        let behavior = if rng.chance(p.bernoulli_frac) {
            BranchBehavior::Bernoulli { p: p.bernoulli_p + rng.f64() * 0.15 }
        } else if rng.chance(0.4) {
            let period = 2 + rng.below(14) as u32;
            BranchBehavior::Pattern { pattern: rng.next_u64(), period }
        } else {
            BranchBehavior::Loop { iters: rng.geometric(p.loop_iters).clamp(2, 64) }
        };
        let target = bi + 1 + rng.index(nblocks - bi - 1);
        return Terminator::CondBranch { target, behavior };
    }
    if not_last && rng.chance(0.7) {
        Terminator::FallThrough
    } else if bi + 2 < nblocks {
        Terminator::Jump { target: bi + 1 + rng.index(nblocks - bi - 1) }
    } else {
        Terminator::Ret
    }
}

/// Ensure structural invariants of the final block of a function.
fn fix_last_block(blocks: &mut [Block]) {
    let n = blocks.len();
    let last = &mut blocks[n - 1].term;
    match last {
        Terminator::FallThrough | Terminator::CondBranch { .. } | Terminator::Call { .. } => {
            *last = Terminator::Ret
        }
        _ => {}
    }
    // Guarantee at least one Ret is reachable: make the last block Ret.
    blocks[n - 1].term = Terminator::Ret;
}

/// Stack region base (shared with the executor).
pub const STACK_REGION: u64 = STACK_BASE;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_validates_for_many_seeds() {
        let p = Personality::default();
        for seed in 0..32 {
            let prog = build_program(&p, seed);
            assert!(prog.static_size() > 10);
        }
    }

    #[test]
    fn build_is_deterministic() {
        let p = Personality::default();
        let a = build_program(&p, 123);
        let b = build_program(&p, 123);
        assert_eq!(a.static_size(), b.static_size());
        assert_eq!(a.funcs.len(), b.funcs.len());
        assert_eq!(
            a.funcs[0].blocks[0].insts.len(),
            b.funcs[0].blocks[0].insts.len()
        );
    }

    #[test]
    fn memory_heavy_personality_has_mem_ops() {
        let p = Personality { load_frac: 0.5, store_frac: 0.2, ..Default::default() };
        let prog = build_program(&p, 5);
        let mem = prog
            .funcs
            .iter()
            .flat_map(|f| &f.blocks)
            .flat_map(|b| &b.insts)
            .filter(|i| i.mem.is_some())
            .count();
        let total: usize = prog.funcs.iter().flat_map(|f| &f.blocks).map(|b| b.insts.len()).sum();
        assert!(mem * 3 > total, "mem={mem} total={total}");
    }

    #[test]
    fn functions_end_with_ret() {
        let prog = build_program(&Personality::default(), 77);
        for f in &prog.funcs {
            assert!(matches!(f.blocks.last().unwrap().term, Terminator::Ret));
        }
    }
}
