//! [`JobRequest`] — a [`super::Simulation`] captured as plain data.
//!
//! The job server's wire protocol ships simulation jobs between
//! processes, so the builder's borrowed fields (records, config,
//! predictor handle) are replaced with owned, serializable descriptions:
//! a [`JobSource`] instead of `&[TraceRecord]`, a [`ConfigSpec`] instead
//! of `&SimConfig`, and a [`PredictorSpec`] by value. A request
//! round-trips through single-line JSON ([`JobRequest::to_json`] /
//! [`JobRequest::from_json`]) with strict unknown-field rejection — a
//! misspelled knob is a named error listing the accepted keys, never a
//! silently-defaulted run.
//!
//! [`JobRequest::run_with`] replays the request through the ordinary
//! [`super::Simulation`] builder against a caller-supplied predictor, so
//! a daemon-side run is byte-identical to the in-process run the same
//! flags would have produced (pinned by `tests/server_e2e.rs`).

use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::EngineOptions;
use crate::des::{BpChoice, SimConfig};
use crate::predictor::LatencyPredictor;
use crate::reports::REFERENCE_SEED;
use crate::server::json::{check_keys, Value};
use crate::trace::{InputStats, RecordStore, TraceSource};
use crate::workload::find;

use super::{ExecMode, PredictorSpec, SimReport, Simulation, WeightsSource};

/// Where a job's instruction trace comes from — the owned counterpart of
/// the builder's `.bench(..)` / `.trace_file(..)` sources (caller-held
/// record slices cannot cross the wire).
#[derive(Debug, Clone, PartialEq)]
pub enum JobSource {
    /// Run the reference DES over a named benchmark for `n` instructions.
    Bench {
        /// Benchmark name (must be in the suite; see `repro list-benches`).
        name: String,
        /// Instructions to simulate.
        n: u64,
    },
    /// Replay an `.smt` trace file readable by the server process.
    TraceFile(PathBuf),
}

impl JobSource {
    /// The unified [`TraceSource`] this wire source resolves through —
    /// `mmap` is the job's read-path switch, applied to trace files.
    pub fn to_trace_source(&self, mmap: bool) -> TraceSource<'static> {
        match self {
            JobSource::Bench { name, n } => TraceSource::bench(name.clone(), *n),
            JobSource::TraceFile(path) => TraceSource::File { path: path.clone(), mmap },
        }
    }
}

/// A machine configuration as data: a named base plus the same overrides
/// the CLI's `--bp` / `--l2-kb` / `--rob` flags apply. [`build`](Self::build)
/// reproduces the CLI's construction exactly, so daemon jobs and direct
/// runs simulate identical machines.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigSpec {
    /// Base configuration name: `"o3"` or `"a64fx"`.
    pub base: String,
    /// Branch predictor override (`bimode` | `bimode-l` | `tage`).
    pub bp: Option<String>,
    /// L2 capacity override in KiB.
    pub l2_kb: Option<u64>,
    /// Reorder-buffer entries override.
    pub rob: Option<usize>,
}

impl ConfigSpec {
    /// The default out-of-order machine with no overrides.
    pub fn o3() -> Self {
        ConfigSpec { base: "o3".into(), bp: None, l2_kb: None, rob: None }
    }

    /// Materialize the [`SimConfig`] this spec describes.
    pub fn build(&self) -> Result<SimConfig> {
        let mut cfg = match self.base.as_str() {
            "o3" => SimConfig::default_o3(),
            "a64fx" => SimConfig::a64fx(),
            other => bail!("unknown config base {other} (o3|a64fx)"),
        };
        if let Some(bp) = &self.bp {
            cfg.bp = match bp.as_str() {
                "bimode" => BpChoice::BiMode,
                "bimode-l" => BpChoice::BiModeLarge,
                "tage" => BpChoice::TageLite,
                other => bail!("unknown branch predictor {other} (bimode|bimode-l|tage)"),
            };
        }
        if let Some(kb) = self.l2_kb {
            cfg.l2.size = kb << 10;
        }
        if let Some(rob) = self.rob {
            cfg.rob_entries = rob;
        }
        Ok(cfg)
    }
}

impl Default for ConfigSpec {
    fn default() -> Self {
        Self::o3()
    }
}

/// Admission priority class. High-priority jobs are dequeued before any
/// normal job, FIFO within each class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    /// Default class.
    Normal,
    /// Dequeued ahead of every queued normal job.
    High,
}

impl Priority {
    /// Stable lowercase name (`"normal"` / `"high"`), used on the wire.
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Inverse of [`as_str`](Self::as_str).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => bail!("unknown priority {other} (normal|high)"),
        }
    }
}

/// One simulation job as owned data: source, machine, predictor, and the
/// execution knobs of [`super::Simulation`].
///
/// # Examples
///
/// ```
/// use simnet::api::job::{JobRequest, JobSource};
/// use simnet::api::PredictorSpec;
///
/// let job = JobRequest::new(
///     JobSource::Bench { name: "xz".into(), n: 1_000 },
///     PredictorSpec::table(8),
/// );
/// let wire = job.to_json();
/// let back = JobRequest::from_json(&wire)?;
/// assert_eq!(back.to_json(), wire);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Trace source.
    pub source: JobSource,
    /// Machine configuration.
    pub config: ConfigSpec,
    /// Predictor selection (the daemon warms one predictor per distinct
    /// [`predictor_key`](Self::predictor_key)).
    pub predictor: PredictorSpec,
    /// Sub-trace parallelism (> 1 selects the batching engine).
    pub subtraces: usize,
    /// Concurrent shards of one shared engine (> 1 selects pool mode).
    pub workers: usize,
    /// CPI window in instructions (0 = none).
    pub window: u64,
    /// Configuration input feature for conditioned models (0.0 = unused).
    pub cfg_feature: f32,
    /// Workload input seed for bench sources.
    pub input_seed: u64,
    /// Engine execution knobs.
    pub engine: EngineOptions,
    /// Admission priority class.
    pub priority: Priority,
    /// Whether trace-file sources may take the zero-copy mmap read path
    /// (default: true; targets without the syscall shim fall back to the
    /// buffered reader regardless).
    pub mmap: bool,
    /// Whether mmap-able trace files stream through bounded decode
    /// windows instead of a full up-front decode (default: true; see
    /// [`super::Simulation::streaming`]). Results are bit-identical
    /// either way.
    pub streaming: bool,
}

/// Accepted top-level keys of the job JSON object, in canonical order.
const JOB_KEYS: &[&str] = &[
    "source",
    "config",
    "predictor",
    "subtraces",
    "workers",
    "window",
    "cfg_feature",
    "input_seed",
    "engine",
    "priority",
    "mmap",
    "streaming",
];

impl JobRequest {
    /// A job with the given source and predictor and every knob at the
    /// [`super::Simulation`] default (sequential, o3 machine, reference
    /// input seed, normal priority).
    pub fn new(source: JobSource, predictor: PredictorSpec) -> Self {
        JobRequest {
            source,
            config: ConfigSpec::o3(),
            predictor,
            subtraces: 1,
            workers: 1,
            window: 0,
            cfg_feature: 0.0,
            input_seed: REFERENCE_SEED,
            engine: EngineOptions::default(),
            priority: Priority::Normal,
            mmap: true,
            streaming: true,
        }
    }

    /// The execution mode [`super::Simulation::run`] will select for
    /// these knobs (same rule: workers, then sub-traces / config
    /// feature, else sequential).
    pub fn mode(&self) -> ExecMode {
        if self.workers.max(1) > 1 {
            ExecMode::Pool
        } else if self.subtraces.max(1) > 1 || self.cfg_feature != 0.0 {
            ExecMode::Engine
        } else {
            ExecMode::Sequential
        }
    }

    /// Identity of the predictor this job needs, as a stable string.
    /// Jobs with equal keys share one warm predictor registry entry in
    /// the server — and are candidates for cross-tenant co-batching.
    pub fn predictor_key(&self) -> String {
        fn wkey(w: &WeightsSource) -> String {
            match w {
                WeightsSource::Auto => "auto".into(),
                WeightsSource::Init => "init".into(),
                WeightsSource::Path(p) => format!("path:{}", p.display()),
            }
        }
        match &self.predictor {
            PredictorSpec::Table { seq } => format!("table/seq={seq}"),
            PredictorSpec::Ml { artifacts, model, weights } => {
                format!("pjrt/{}/{}/w={}", artifacts.display(), model, wkey(weights))
            }
            PredictorSpec::Native { artifacts, model, weights, seq } => {
                format!(
                    "native/{}/{}/seq={}/w={}",
                    artifacts.display(),
                    model,
                    seq,
                    wkey(weights)
                )
            }
        }
    }

    /// Total instructions the job will simulate, when knowable up front
    /// (bench sources; trace files are sized only once read).
    pub fn total_instructions(&self) -> Option<u64> {
        match &self.source {
            JobSource::Bench { n, .. } => Some(*n),
            JobSource::TraceFile(_) => None,
        }
    }

    /// Check the request without running it: the benchmark must exist,
    /// the config must build, and the predictor spec must validate.
    /// (Trace-file existence is checked at run time, by the open.)
    pub fn validate(&self) -> Result<()> {
        if let JobSource::Bench { name, .. } = &self.source {
            if find(name).is_none() {
                bail!("unknown benchmark {name}");
            }
        }
        self.config.build()?;
        self.predictor.validate()
    }

    /// Execute the request against an already-built predictor (the
    /// server's warm registry entry), optionally streaming progress
    /// through `counter`. Equivalent to building a
    /// [`super::Simulation`] with the same knobs — pinned byte-identical
    /// by `tests/server_e2e.rs`.
    pub fn run_with(
        &self,
        predictor: &mut dyn LatencyPredictor,
        counter: Option<Arc<AtomicU64>>,
    ) -> Result<SimReport> {
        let cfg = self.config.build()?;
        let mut sim = Simulation::new()
            .config(&cfg)
            .predictor_ref(predictor)
            .labeled(self.predictor.label())
            .subtraces(self.subtraces)
            .workers(self.workers)
            .window(self.window)
            .cfg_feature(self.cfg_feature)
            .input_seed(self.input_seed)
            .engine(self.engine)
            .streaming(self.streaming)
            .source(self.source.to_trace_source(self.mmap));
        if let Some(c) = counter {
            sim = sim.progress(c);
        }
        sim.run()
    }

    /// Materialize the record store this job simulates, plus the
    /// reference CPI, bench name, and input byte accounting for its
    /// report — the pieces the server's co-batching path feeds into one
    /// shared engine. Resolved through the same [`TraceSource`] code
    /// path as [`super::Simulation::run`]; streaming jobs come back as a
    /// bounded-window mapped store, so concurrent tenants stop holding
    /// whole decoded traces.
    pub(crate) fn materialize_store(
        &self,
        cfg: &SimConfig,
    ) -> Result<(RecordStore<'static>, Option<f64>, Option<String>, InputStats)> {
        let source = self.source.to_trace_source(self.mmap);
        let (store, cpi, bench, input) =
            super::resolve_source(&source, cfg, self.input_seed, true, self.streaming, 0)?;
        Ok((store.into_static(), cpi, bench, input))
    }

    /// Render the request as one single-line JSON object (the wire form;
    /// canonical, so `from_json(to_json(j)).to_json() == to_json(j)`).
    pub fn to_json(&self) -> String {
        self.to_value().render()
    }

    fn to_value(&self) -> Value {
        let source = match &self.source {
            JobSource::Bench { name, n } => Value::Obj(vec![
                ("bench".into(), Value::Str(name.clone())),
                ("n".into(), Value::Num(*n as f64)),
            ]),
            JobSource::TraceFile(path) => Value::Obj(vec![(
                "trace".into(),
                Value::Str(path.display().to_string()),
            )]),
        };
        let mut config = vec![("base".into(), Value::Str(self.config.base.clone()))];
        if let Some(bp) = &self.config.bp {
            config.push(("bp".into(), Value::Str(bp.clone())));
        }
        if let Some(kb) = self.config.l2_kb {
            config.push(("l2_kb".into(), Value::Num(kb as f64)));
        }
        if let Some(rob) = self.config.rob {
            config.push(("rob".into(), Value::Num(rob as f64)));
        }
        let weights = |w: &WeightsSource| match w {
            WeightsSource::Auto => Value::Str("auto".into()),
            WeightsSource::Init => Value::Str("init".into()),
            WeightsSource::Path(p) => {
                Value::Obj(vec![("path".into(), Value::Str(p.display().to_string()))])
            }
        };
        let predictor = match &self.predictor {
            PredictorSpec::Table { seq } => Value::Obj(vec![
                ("kind".into(), Value::Str("table".into())),
                ("seq".into(), Value::Num(*seq as f64)),
            ]),
            PredictorSpec::Ml { artifacts, model, weights: w } => Value::Obj(vec![
                ("kind".into(), Value::Str("pjrt".into())),
                ("artifacts".into(), Value::Str(artifacts.display().to_string())),
                ("model".into(), Value::Str(model.clone())),
                ("weights".into(), weights(w)),
            ]),
            PredictorSpec::Native { artifacts, model, weights: w, seq } => Value::Obj(vec![
                ("kind".into(), Value::Str("native".into())),
                ("artifacts".into(), Value::Str(artifacts.display().to_string())),
                ("model".into(), Value::Str(model.clone())),
                ("weights".into(), weights(w)),
                ("seq".into(), Value::Num(*seq as f64)),
            ]),
        };
        let engine = Value::Obj(vec![
            ("target_batch".into(), Value::Num(self.engine.target_batch as f64)),
            ("encode_threads".into(), Value::Num(self.engine.encode_threads as f64)),
            ("pipeline_depth".into(), Value::Num(self.engine.pipeline_depth as f64)),
            ("fork_predict".into(), Value::Bool(self.engine.fork_predict)),
        ]);
        Value::Obj(vec![
            ("source".into(), source),
            ("config".into(), config_value(config)),
            ("predictor".into(), predictor),
            ("subtraces".into(), Value::Num(self.subtraces as f64)),
            ("workers".into(), Value::Num(self.workers as f64)),
            ("window".into(), Value::Num(self.window as f64)),
            ("cfg_feature".into(), Value::Num(self.cfg_feature as f64)),
            ("input_seed".into(), Value::Num(self.input_seed as f64)),
            ("engine".into(), engine),
            ("priority".into(), Value::Str(self.priority.as_str().into())),
            ("mmap".into(), Value::Bool(self.mmap)),
            ("streaming".into(), Value::Bool(self.streaming)),
        ])
    }

    /// Parse a request from its JSON wire form. Unknown fields at any
    /// level are rejected by name, listing the keys that object accepts.
    pub fn from_json(s: &str) -> Result<JobRequest> {
        Self::from_value(&Value::parse(s)?)
    }

    /// [`from_json`](Self::from_json) over an already-parsed [`Value`]
    /// (the server parses the enclosing protocol line once).
    pub fn from_value(v: &Value) -> Result<JobRequest> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("job: expected a JSON object"))?;
        check_keys(obj, "job", JOB_KEYS)?;
        let source =
            source_from(v.get("source").ok_or_else(|| anyhow!("job: missing \"source\""))?)?;
        let predictor = predictor_from(
            v.get("predictor").ok_or_else(|| anyhow!("job: missing \"predictor\""))?,
        )?;
        let mut job = JobRequest::new(source, predictor);
        if let Some(c) = v.get("config") {
            job.config = config_from(c)?;
        }
        if let Some(x) = v.get("subtraces") {
            job.subtraces = get_u64(x, "subtraces")? as usize;
        }
        if let Some(x) = v.get("workers") {
            job.workers = get_u64(x, "workers")? as usize;
        }
        if let Some(x) = v.get("window") {
            job.window = get_u64(x, "window")?;
        }
        if let Some(x) = v.get("cfg_feature") {
            job.cfg_feature =
                x.as_f64().ok_or_else(|| anyhow!("job: \"cfg_feature\" must be a number"))? as f32;
        }
        if let Some(x) = v.get("input_seed") {
            job.input_seed = get_u64(x, "input_seed")?;
        }
        if let Some(e) = v.get("engine") {
            job.engine = engine_from(e)?;
        }
        if let Some(p) = v.get("priority") {
            let s = p.as_str().ok_or_else(|| anyhow!("job: \"priority\" must be a string"))?;
            job.priority = Priority::parse(s)?;
        }
        if let Some(m) = v.get("mmap") {
            job.mmap = m.as_bool().ok_or_else(|| anyhow!("job: \"mmap\" must be a bool"))?;
        }
        if let Some(s) = v.get("streaming") {
            job.streaming =
                s.as_bool().ok_or_else(|| anyhow!("job: \"streaming\" must be a bool"))?;
        }
        Ok(job)
    }
}

/// Wrap the config pair list, defaulting an all-defaults spec to the
/// bare object form `{"base": "o3"}` (already the case by construction).
fn config_value(pairs: Vec<(String, Value)>) -> Value {
    Value::Obj(pairs)
}

/// A non-negative integer member (bounded to the f64-exact range by the
/// parser's [`Value::as_u64`]).
fn get_u64(v: &Value, name: &str) -> Result<u64> {
    v.as_u64().ok_or_else(|| {
        anyhow!("job: \"{name}\" must be a non-negative integer (at most 2^53)")
    })
}

fn source_from(v: &Value) -> Result<JobSource> {
    let obj = v.as_obj().ok_or_else(|| anyhow!("job source: expected a JSON object"))?;
    check_keys(obj, "job source", &["bench", "n", "trace"])?;
    match (v.get("bench"), v.get("trace")) {
        (Some(b), None) => {
            let name =
                b.as_str().ok_or_else(|| anyhow!("job source: \"bench\" must be a string"))?;
            let n = get_u64(
                v.get("n").ok_or_else(|| anyhow!("job source: bench needs \"n\""))?,
                "n",
            )?;
            Ok(JobSource::Bench { name: name.to_string(), n })
        }
        (None, Some(t)) => {
            if v.get("n").is_some() {
                bail!("job source: \"n\" only applies to bench sources");
            }
            let path =
                t.as_str().ok_or_else(|| anyhow!("job source: \"trace\" must be a string"))?;
            Ok(JobSource::TraceFile(PathBuf::from(path)))
        }
        _ => bail!("job source: exactly one of \"bench\" or \"trace\" is required"),
    }
}

fn config_from(v: &Value) -> Result<ConfigSpec> {
    let obj = v.as_obj().ok_or_else(|| anyhow!("job config: expected a JSON object"))?;
    check_keys(obj, "job config", &["base", "bp", "l2_kb", "rob"])?;
    let mut spec = ConfigSpec::o3();
    if let Some(b) = v.get("base") {
        spec.base = b
            .as_str()
            .ok_or_else(|| anyhow!("job config: \"base\" must be a string"))?
            .to_string();
    }
    if let Some(bp) = v.get("bp") {
        spec.bp = Some(
            bp.as_str()
                .ok_or_else(|| anyhow!("job config: \"bp\" must be a string"))?
                .to_string(),
        );
    }
    if let Some(kb) = v.get("l2_kb") {
        spec.l2_kb = Some(get_u64(kb, "l2_kb")?);
    }
    if let Some(rob) = v.get("rob") {
        spec.rob = Some(get_u64(rob, "rob")? as usize);
    }
    // Surface bad base / bp names at admission, not mid-run.
    spec.build().context("job config")?;
    Ok(spec)
}

fn weights_from(v: &Value) -> Result<WeightsSource> {
    match v {
        Value::Str(s) if s == "auto" => Ok(WeightsSource::Auto),
        Value::Str(s) if s == "init" => Ok(WeightsSource::Init),
        Value::Str(s) => {
            bail!("job predictor: unknown weights \"{s}\" (auto|init|{{\"path\": ..}})")
        }
        Value::Obj(pairs) => {
            check_keys(pairs, "job predictor weights", &["path"])?;
            let p = v
                .get("path")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("job predictor weights: \"path\" must be a string"))?;
            Ok(WeightsSource::Path(PathBuf::from(p)))
        }
        _ => bail!("job predictor: \"weights\" must be \"auto\", \"init\", or {{\"path\": ..}}"),
    }
}

fn predictor_from(v: &Value) -> Result<PredictorSpec> {
    let obj = v.as_obj().ok_or_else(|| anyhow!("job predictor: expected a JSON object"))?;
    let kind = v
        .get("kind")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("job predictor: missing \"kind\" (table|pjrt|native)"))?;
    let artifacts = || -> Result<PathBuf> {
        Ok(match v.get("artifacts") {
            None => PathBuf::from("artifacts"),
            Some(a) => PathBuf::from(
                a.as_str()
                    .ok_or_else(|| anyhow!("job predictor: \"artifacts\" must be a string"))?,
            ),
        })
    };
    let model = || -> Result<String> {
        Ok(v.get("model")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("job predictor: missing \"model\""))?
            .to_string())
    };
    let seq = |default: usize| -> Result<usize> {
        Ok(match v.get("seq") {
            None => default,
            Some(s) => get_u64(s, "seq")? as usize,
        })
    };
    let weights = || -> Result<WeightsSource> {
        match v.get("weights") {
            None => Ok(WeightsSource::Auto),
            Some(w) => weights_from(w),
        }
    };
    match kind {
        "table" => {
            check_keys(obj, "job predictor (table)", &["kind", "seq"])?;
            Ok(PredictorSpec::Table { seq: seq(32)? })
        }
        "pjrt" => {
            check_keys(obj, "job predictor (pjrt)", &["kind", "artifacts", "model", "weights"])?;
            Ok(PredictorSpec::Ml { artifacts: artifacts()?, model: model()?, weights: weights()? })
        }
        "native" => {
            check_keys(
                obj,
                "job predictor (native)",
                &["kind", "artifacts", "model", "weights", "seq"],
            )?;
            Ok(PredictorSpec::Native {
                artifacts: artifacts()?,
                model: model()?,
                weights: weights()?,
                seq: seq(32)?,
            })
        }
        other => bail!("job predictor: unknown kind \"{other}\" (table|pjrt|native)"),
    }
}

fn engine_from(v: &Value) -> Result<EngineOptions> {
    let obj = v.as_obj().ok_or_else(|| anyhow!("job engine: expected a JSON object"))?;
    check_keys(
        obj,
        "job engine",
        &["target_batch", "encode_threads", "pipeline_depth", "fork_predict"],
    )?;
    let mut opts = EngineOptions::default();
    if let Some(x) = v.get("target_batch") {
        opts.target_batch = get_u64(x, "target_batch")? as usize;
    }
    if let Some(x) = v.get("encode_threads") {
        opts.encode_threads = (get_u64(x, "encode_threads")? as usize).max(1);
    }
    if let Some(x) = v.get("pipeline_depth") {
        opts.pipeline_depth = (get_u64(x, "pipeline_depth")? as usize).max(1);
    }
    if let Some(x) = v.get("fork_predict") {
        opts.fork_predict =
            x.as_bool().ok_or_else(|| anyhow!("job engine: \"fork_predict\" must be a bool"))?;
    }
    Ok(opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_request() -> JobRequest {
        let mut job = JobRequest::new(
            JobSource::Bench { name: "gcc".into(), n: 5_000 },
            PredictorSpec::native("artifacts", "fc2", 8).with_weights_source(WeightsSource::Init),
        );
        job.config = ConfigSpec {
            base: "o3".into(),
            bp: Some("tage".into()),
            l2_kb: Some(512),
            rob: Some(192),
        };
        job.subtraces = 4;
        job.workers = 2;
        job.window = 500;
        job.input_seed = 7;
        job.engine.target_batch = 8;
        job.priority = Priority::High;
        job.mmap = false;
        job.streaming = false;
        job
    }

    #[test]
    fn json_roundtrip_is_canonical() {
        let job = full_request();
        let wire = job.to_json();
        assert!(!wire.contains('\n'), "wire form must be one line");
        let back = JobRequest::from_json(&wire).unwrap();
        assert_eq!(back.to_json(), wire);
        assert_eq!(back.priority, Priority::High);
        assert_eq!(back.config, job.config);
        assert_eq!(back.predictor_key(), job.predictor_key());
        assert!(!back.mmap, "mmap switch must survive the wire");
        assert!(!back.streaming, "streaming switch must survive the wire");

        // Minimal form: only source + predictor, everything else default.
        let small = JobRequest::new(
            JobSource::TraceFile(PathBuf::from("/tmp/t.smt")),
            PredictorSpec::table(16),
        );
        let back = JobRequest::from_json(&small.to_json()).unwrap();
        assert_eq!(back.to_json(), small.to_json());
        assert_eq!(back.source, small.source);
    }

    #[test]
    fn unknown_fields_are_named_with_accepted_list() {
        let cases = [
            ("{\"sauce\": 1}", "unknown field \"sauce\""),
            ("{\"sauce\": 1}", "accepted: source, config, predictor"),
            (
                "{\"source\": {\"bench\": \"gcc\", \"m\": 1}, \
                 \"predictor\": {\"kind\": \"table\"}}",
                "accepted: bench, n, trace",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \
                 \"predictor\": {\"kind\": \"table\", \"model\": \"x\"}}",
                "accepted: kind, seq",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \
                 \"predictor\": {\"kind\": \"table\"}, \"config\": {\"cache\": 1}}",
                "accepted: base, bp, l2_kb, rob",
            ),
        ];
        for (input, needle) in cases {
            let err = JobRequest::from_json(input).unwrap_err().to_string();
            assert!(err.contains(needle), "input {input}: err {err:?}");
        }
    }

    #[test]
    fn malformed_requests_are_named_errors() {
        for (input, needle) in [
            ("[]", "expected a JSON object"),
            ("{\"predictor\": {\"kind\": \"table\"}}", "missing \"source\""),
            ("{\"source\": {\"bench\": \"gcc\", \"n\": 1}}", "missing \"predictor\""),
            (
                "{\"source\": {}, \"predictor\": {\"kind\": \"table\"}}",
                "exactly one of \"bench\" or \"trace\"",
            ),
            (
                "{\"source\": {\"trace\": \"t\", \"n\": 5}, \"predictor\": {\"kind\": \"table\"}}",
                "only applies to bench",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \"predictor\": {\"kind\": \"x\"}}",
                "unknown kind",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \
                 \"predictor\": {\"kind\": \"pjrt\"}}",
                "missing \"model\"",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \
                 \"predictor\": {\"kind\": \"table\"}, \"subtraces\": 1.5}",
                "non-negative integer",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \
                 \"predictor\": {\"kind\": \"table\"}, \"priority\": \"urgent\"}",
                "unknown priority",
            ),
            (
                "{\"source\": {\"bench\": \"gcc\", \"n\": 1}, \
                 \"predictor\": {\"kind\": \"table\"}, \"config\": {\"bp\": \"gshare\"}}",
                "unknown branch predictor",
            ),
        ] {
            let err = JobRequest::from_json(input).unwrap_err().to_string();
            assert!(err.contains(needle), "input {input}: err {err:?}");
        }
    }

    #[test]
    fn config_spec_matches_cli_construction() {
        let spec = ConfigSpec {
            base: "o3".into(),
            bp: Some("tage".into()),
            l2_kb: Some(512),
            rob: Some(192),
        };
        let cfg = spec.build().unwrap();
        assert_eq!(cfg.l2.size, 512 << 10);
        assert_eq!(cfg.rob_entries, 192);
        assert!(matches!(cfg.bp, BpChoice::TageLite));
        assert!(ConfigSpec { base: "vax".into(), ..ConfigSpec::o3() }.build().is_err());
    }

    #[test]
    fn mode_and_key_follow_knobs() {
        let mut job = JobRequest::new(
            JobSource::Bench { name: "xz".into(), n: 100 },
            PredictorSpec::table(8),
        );
        assert_eq!(job.mode(), ExecMode::Sequential);
        assert_eq!(job.predictor_key(), "table/seq=8");
        assert_eq!(job.total_instructions(), Some(100));
        job.subtraces = 4;
        assert_eq!(job.mode(), ExecMode::Engine);
        job.workers = 2;
        assert_eq!(job.mode(), ExecMode::Pool);

        // Same spec fields, same key — different seq, different key.
        let a = JobRequest::new(
            JobSource::Bench { name: "xz".into(), n: 1 },
            PredictorSpec::native("artifacts", "fc2", 8),
        );
        let b = JobRequest::new(
            JobSource::Bench { name: "gcc".into(), n: 2 },
            PredictorSpec::native("artifacts", "fc2", 8),
        );
        assert_eq!(a.predictor_key(), b.predictor_key());
        let c = JobRequest::new(
            JobSource::Bench { name: "xz".into(), n: 1 },
            PredictorSpec::native("artifacts", "fc2", 16),
        );
        assert_ne!(a.predictor_key(), c.predictor_key());
    }

    #[test]
    fn validate_names_bad_benchmarks() {
        let job = JobRequest::new(
            JobSource::Bench { name: "not_a_bench".into(), n: 10 },
            PredictorSpec::table(8),
        );
        let err = job.validate().unwrap_err().to_string();
        assert!(err.contains("not_a_bench"), "err: {err}");
        assert!(full_request().validate().is_ok());
    }

    #[test]
    fn run_with_matches_direct_simulation() {
        // Sequential and engine runs through a JobRequest must reproduce
        // the direct builder byte-for-byte (cycles and windows).
        for subtraces in [1usize, 4] {
            let mut job = JobRequest::new(
                JobSource::Bench { name: "xz".into(), n: 1_000 },
                PredictorSpec::table(8),
            );
            job.subtraces = subtraces;
            job.window = 250;
            let mut p = job.predictor.build().unwrap();
            let via_job = job.run_with(p.as_mut(), None).unwrap();

            let direct = Simulation::new()
                .bench("xz", 1_000)
                .predictor(PredictorSpec::table(8))
                .subtraces(subtraces)
                .window(250)
                .run()
                .unwrap();
            assert_eq!(via_job.mode, direct.mode);
            assert_eq!(via_job.outcome.cycles, direct.outcome.cycles);
            assert_eq!(via_job.outcome.windows, direct.outcome.windows);
            assert_eq!(via_job.predictor, direct.predictor);
        }
    }
}
