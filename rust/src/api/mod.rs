//! # The unified simulation session API
//!
//! One builder, one predictor spec, one machine-readable report —
//! whatever execution mode a run needs.
//!
//! SimNet's core claim (paper §3.2–3.3) is that a single ML latency
//! predictor serves every simulation style: sequential, sub-trace
//! parallel, dynamically batched, and pooled across concurrent jobs.
//! This module makes that true at the API level too: every report,
//! sweep, CLI command, and bench constructs its runs through
//! [`Simulation`], selects predictors with [`PredictorSpec`] — the
//! analytical table, the PJRT backend, or the pure-Rust native backend
//! ([`Backend`], [`WeightsSource`]) — and gets a [`SimReport`] back,
//! including the JSON the `repro simulate-ml --json` flag and the bench
//! harnesses emit.
//!
//! ```no_run
//! use simnet::api::{PredictorSpec, Simulation};
//!
//! # fn main() -> anyhow::Result<()> {
//! // Sequential run over a benchmark with the analytical predictor.
//! let report = Simulation::new()
//!     .bench("gcc", 20_000)
//!     .predictor(PredictorSpec::table(32))
//!     .run()?;
//! println!("cpi={:.3} err={:.2}%", report.cpi(), report.cpi_error().unwrap() * 100.0);
//!
//! // Same session, batched + pooled: the knobs pick the execution mode.
//! let report = Simulation::new()
//!     .bench("gcc", 20_000)
//!     .predictor(PredictorSpec::table(32))
//!     .subtraces(256)
//!     .workers(4)
//!     .run()?;
//! std::fs::write("report.json", report.to_json())?;
//! # Ok(())
//! # }
//! ```
//!
//! [`Simulation::run`] picks the mode from the knobs:
//!
//! | knobs | mode | backend |
//! |---|---|---|
//! | defaults | [`ExecMode::Sequential`] | [`crate::coordinator::simulate_sequential`] |
//! | `.subtraces(n > 1)` (or a config feature) | [`ExecMode::Engine`] | one [`crate::coordinator::BatchEngine`] job |
//! | `.workers(n > 1)` | [`ExecMode::Pool`] | trace sharded over jobs of one shared engine |
//!
//! All three are byte-identical to the underlying entry points they wrap
//! (pinned by `tests/api_equivalence.rs`).

// The api tree is the public face of the crate: every public item must
// carry documentation (CI compiles docs with RUSTDOCFLAGS=-D warnings).
#![warn(missing_docs)]

pub mod job;
pub mod report;
pub mod spec;

use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

pub use report::{ExecMode, SimReport};
pub use spec::{export_name, Backend, PredictorSpec, WeightsSource};

use crate::coordinator::{
    simulate_pool_view, simulate_sequential_view, BatchEngine, EngineOptions, JobSpec, PoolOptions,
};
use crate::des::SimConfig;
use crate::predictor::LatencyPredictor;
use crate::reports::{des_trace, REFERENCE_SEED};
use crate::trace::mmap::MmapTrace;
use crate::trace::{open_store, InputStats, RecordStore, TraceRecord, TraceSource};
use crate::workload::find;

/// Where a run's predictor comes from.
enum Predictor<'a> {
    Unset,
    /// Built from a spec at run time.
    Spec(PredictorSpec),
    /// Borrowed, so callers can reuse one predictor (and its served /
    /// artifact state) across many runs.
    Borrowed(&'a mut dyn LatencyPredictor),
}

/// Builder for one simulation session. See the [module docs](self) for
/// the mode-selection table and a full example.
///
/// # Examples
///
/// ```
/// use simnet::api::{ExecMode, PredictorSpec, Simulation};
///
/// let report = Simulation::new()
///     .bench("xz", 2_000) // reference DES generates the trace
///     .predictor(PredictorSpec::table(8))
///     .subtraces(4) // > 1 selects the batching engine
///     .run()?;
/// assert_eq!(report.mode, ExecMode::Engine);
/// assert_eq!(report.outcome.instructions, 2_000);
/// assert!(report.engine.is_some(), "engine mode reports batching stats");
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct Simulation<'a> {
    source: Option<TraceSource<'a>>,
    cfg: Option<&'a SimConfig>,
    predictor: Predictor<'a>,
    label: Option<String>,
    subtraces: usize,
    workers: usize,
    engine: EngineOptions,
    window: u64,
    cfg_feature: f32,
    seed: u64,
    mmap: bool,
    streaming: bool,
    stream_window: usize,
    progress: Option<Arc<AtomicU64>>,
}

impl Default for Simulation<'_> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> Simulation<'a> {
    /// A session with the default o3 machine, sequential execution, and
    /// the reference input seed; input and predictor must still be set.
    pub fn new() -> Self {
        Simulation {
            source: None,
            cfg: None,
            predictor: Predictor::Unset,
            label: None,
            subtraces: 1,
            workers: 1,
            engine: EngineOptions::default(),
            window: 0,
            cfg_feature: 0.0,
            seed: REFERENCE_SEED,
            mmap: true,
            streaming: true,
            stream_window: 0,
            progress: None,
        }
    }

    /// Set the input from a [`TraceSource`] value — the unified input
    /// shape shared with the CLI and the job server. The convenience
    /// builders below ([`records`](Self::records), [`bench`](Self::bench),
    /// [`trace_file`](Self::trace_file)) are thin wrappers over this.
    pub fn source(mut self, source: TraceSource<'a>) -> Self {
        self.source = Some(source);
        self
    }

    /// Simulate caller-held trace records (the reference CPI is derived
    /// from the records' own fetch latencies).
    pub fn records(self, records: &'a [TraceRecord]) -> Self {
        self.source(TraceSource::records(records))
    }

    /// Run the reference DES over benchmark `name` for `n` instructions
    /// and simulate the resulting trace (the DES CPI becomes the
    /// reference).
    pub fn bench(self, name: impl Into<String>, n: u64) -> Self {
        self.source(TraceSource::bench(name, n))
    }

    /// Simulate an `.smt` trace file.
    pub fn trace_file(self, path: impl Into<PathBuf>) -> Self {
        self.source(TraceSource::file(path))
    }

    /// Whether trace files may take the zero-copy mmap read path
    /// (default: true). ANDed with the per-[`TraceSource::File`] flag, so
    /// either side can force the buffered path; targets without the
    /// syscall shim fall back regardless.
    pub fn mmap(mut self, on: bool) -> Self {
        self.mmap = on;
        self
    }

    /// Whether mmap-able trace files stream through bounded per-sub-trace
    /// decode windows instead of a full up-front decode (default: true).
    /// Resident memory then stays O(subtraces × window × 64 B) however
    /// large the trace, and results are bit-identical. Only affects
    /// [`TraceSource::File`] inputs on the mmap path; buffered reads fall
    /// back to full decode regardless.
    pub fn streaming(mut self, on: bool) -> Self {
        self.streaming = on;
        self
    }

    /// Streaming decode-window size in records per sub-trace cursor
    /// (0 = [`crate::trace::DEFAULT_STREAM_WINDOW`]). Only consulted when
    /// [`streaming`](Self::streaming) applies.
    pub fn stream_window(mut self, records: usize) -> Self {
        self.stream_window = records;
        self
    }

    /// Machine configuration (default: `SimConfig::default_o3()`).
    /// Borrowed, so sweeps re-running one config need no clone per run.
    pub fn config(mut self, cfg: &'a SimConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Predictor to build for this run.
    pub fn predictor(mut self, spec: PredictorSpec) -> Self {
        self.predictor = Predictor::Spec(spec);
        self
    }

    /// Reuse an already-built predictor (reports that sweep many
    /// configurations build once and pass it here).
    pub fn predictor_ref(mut self, predictor: &'a mut dyn LatencyPredictor) -> Self {
        self.predictor = Predictor::Borrowed(predictor);
        self
    }

    /// Override the predictor label recorded in the report (mainly for
    /// [`predictor_ref`](Self::predictor_ref) runs).
    pub fn labeled(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Sub-trace parallelism (> 1 selects the batching engine).
    pub fn subtraces(mut self, n: usize) -> Self {
        self.subtraces = n;
        self
    }

    /// Concurrent jobs sharing one engine (> 1 selects pool mode;
    /// `subtraces` then counts the total across all jobs).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Engine execution knobs (target batch, encode threads, pipeline
    /// depth); only engine and pool modes consult them.
    pub fn engine(mut self, opts: EngineOptions) -> Self {
        self.engine = opts;
        self
    }

    /// CPI window in instructions (0 = no windows).
    pub fn window(mut self, w: u64) -> Self {
        self.window = w;
        self
    }

    /// Configuration input feature for conditioned models (§5 ROB study);
    /// non-zero values run through the engine so every context tracker
    /// carries the feature.
    pub fn cfg_feature(mut self, f: f32) -> Self {
        self.cfg_feature = f;
        self
    }

    /// Workload input seed for `.bench(..)` sources (default: the
    /// reference seed used by all accuracy reports).
    pub fn input_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Shared counter bumped once per simulated instruction, on every
    /// execution mode — the job server reads it to stream progress
    /// events while [`run`](Self::run) is still executing. Results are
    /// unaffected.
    pub fn progress(mut self, counter: Arc<AtomicU64>) -> Self {
        self.progress = Some(counter);
        self
    }

    /// Execute the session: resolve the input, build (or borrow) the
    /// predictor, pick the execution mode from the knobs, and return the
    /// unified report.
    pub fn run(self) -> Result<SimReport> {
        let Simulation {
            source,
            cfg,
            predictor,
            label,
            subtraces,
            workers,
            engine,
            window,
            cfg_feature,
            seed,
            mmap,
            streaming,
            stream_window,
            progress,
        } = self;

        // Default config is materialized here only when none was given.
        let default_cfg;
        let cfg: &SimConfig = match cfg {
            Some(c) => c,
            None => {
                default_cfg = SimConfig::default_o3();
                &default_cfg
            }
        };

        let source = source.ok_or_else(|| {
            anyhow!("no input: call .records(..), .bench(..), .trace_file(..), or .source(..)")
        })?;
        // resolve_source borrows the caller's records straight through
        // (a Memory store over the slice), so the caller-records path
        // never allocates; streaming file sources come back as a Mapped
        // store whose cursors decode on demand.
        let (store, des_cpi, bench, mut input) =
            resolve_source(&source, cfg, seed, mmap, streaming, stream_window)?;

        let mut built: Option<Box<dyn LatencyPredictor>> = None;
        let (predictor, spec_label): (&mut dyn LatencyPredictor, String) = match predictor {
            Predictor::Unset => {
                bail!("no predictor: call .predictor(spec) or .predictor_ref(..)")
            }
            Predictor::Spec(spec) => {
                let l = spec.label();
                (built.insert(spec.build()?).as_mut(), l)
            }
            Predictor::Borrowed(p) => (p, "external".to_string()),
        };

        let workers = workers.max(1);
        let subtraces = subtraces.max(1);
        let mode = if workers > 1 {
            ExecMode::Pool
        } else if subtraces > 1 || cfg_feature != 0.0 {
            ExecMode::Engine
        } else {
            ExecMode::Sequential
        };

        let view = store.view();
        let (outcome, stats) = match mode {
            ExecMode::Sequential => (
                simulate_sequential_view(view, cfg, predictor, window, progress.as_deref())?,
                None,
            ),
            ExecMode::Engine => {
                let mut eng = BatchEngine::with_options(predictor, engine);
                let spec = JobSpec { records: view, cfg, subtraces, window, cfg_feature, progress };
                eng.submit(spec);
                let report = eng.run()?;
                let stats = report.stats.clone();
                (report.merged(), Some(stats))
            }
            ExecMode::Pool => {
                let opts = PoolOptions { workers, subtraces, window, cfg_feature, engine, progress };
                let (out, stats) = simulate_pool_view(view, cfg, predictor, &opts)?;
                (out, Some(stats))
            }
        };

        // Streaming runs report the observed residency bound (the sum of
        // every cursor's largest decode buffer) now that all cursors are
        // done; full-decode runs recorded theirs at open time.
        if input.window_records > 0 {
            input.peak_resident_records = store.peak_resident_records();
        }

        Ok(SimReport {
            predictor: label.unwrap_or(spec_label),
            mode,
            bench,
            config: cfg.name.to_string(),
            outcome,
            engine: stats,
            des_cpi,
            input,
        })
    }
}

/// Resolve a [`TraceSource`] into the record store to simulate, the
/// reference CPI, the bench name (when the source was a benchmark), and
/// the input byte accounting — the one code path behind the builder, the
/// CLI, and the job server. `mmap` is the session-level switch; a
/// [`TraceSource::File`] takes the zero-copy path only when both its own
/// flag and the session flag allow it, and additionally comes back as a
/// streaming [`RecordStore::Mapped`] (bounded decode windows of
/// `stream_window` records) when `streaming` is on.
pub(crate) fn resolve_source<'a>(
    source: &'a TraceSource<'a>,
    cfg: &SimConfig,
    seed: u64,
    mmap: bool,
    streaming: bool,
    stream_window: usize,
) -> Result<(RecordStore<'a>, Option<f64>, Option<String>, InputStats)> {
    match source {
        TraceSource::Records(r) => Ok((
            RecordStore::from_records(r),
            Some(trace_reference_cpi(r)),
            None,
            InputStats::default(),
        )),
        TraceSource::Bench { name, n } => {
            let b = find(name).ok_or_else(|| anyhow!("unknown benchmark {name}"))?;
            let (recs, stats) = des_trace(cfg, &b, *n, seed);
            let cpi = stats.cpi();
            Ok((RecordStore::from_vec(recs), Some(cpi), Some(name.clone()), InputStats::default()))
        }
        TraceSource::File { path, mmap: file_mmap } => {
            let (store, input) = open_store(path, mmap && *file_mmap, streaming, stream_window)
                .with_context(|| format!("open {}", path.display()))?;
            let cpi = match &store {
                RecordStore::Memory(recs) => trace_reference_cpi(recs),
                RecordStore::Mapped { map, .. } => mapped_reference_cpi(map),
            };
            Ok((store, Some(cpi), None, input))
        }
    }
}

/// Reference CPI embedded in a trace: its own fetch latencies are the
/// per-instruction cycle deltas the DES observed when writing it.
fn trace_reference_cpi(records: &[TraceRecord]) -> f64 {
    let cycles: u64 = records.iter().map(|r| r.f_lat as u64).sum();
    cycles as f64 / records.len().max(1) as f64
}

/// [`trace_reference_cpi`] for a mapped trace: reads each record's
/// fetch-latency field (bytes 48..52) straight out of the mapping, so
/// the reference CPI costs one sequential page scan instead of a full
/// decode. Bit-identical to the in-memory formula.
fn mapped_reference_cpi(map: &MmapTrace) -> f64 {
    let mut cycles = 0u64;
    for i in 0..map.count() {
        let b = map.record_bytes(i);
        cycles += u64::from(u32::from_le_bytes([b[48], b[49], b[50], b[51]]));
    }
    cycles as f64 / (map.count() as usize).max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_without_input_or_predictor_errors() {
        let err = Simulation::new().predictor(PredictorSpec::table(8)).run().unwrap_err();
        assert!(err.to_string().contains("no input"), "err: {err}");
        let err = Simulation::new().bench("gcc", 100).run().unwrap_err();
        assert!(err.to_string().contains("no predictor"), "err: {err}");
    }

    #[test]
    fn unknown_bench_errors() {
        let err = Simulation::new()
            .bench("not_a_bench", 100)
            .predictor(PredictorSpec::table(8))
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("not_a_bench"), "err: {err}");
    }

    #[test]
    fn mode_selection_follows_knobs() {
        let base = || Simulation::new().bench("xz", 400).predictor(PredictorSpec::table(8));
        let r = base().run().unwrap();
        assert_eq!(r.mode, ExecMode::Sequential);
        assert!(r.engine.is_none());
        assert_eq!(r.outcome.instructions, 400);
        let r = base().subtraces(4).run().unwrap();
        assert_eq!(r.mode, ExecMode::Engine);
        assert_eq!(r.engine.as_ref().unwrap().subtraces, 4);
        let r = base().workers(2).subtraces(4).run().unwrap();
        assert_eq!(r.mode, ExecMode::Pool);
        assert_eq!(r.engine.as_ref().unwrap().subtraces, 4);
    }

    #[test]
    fn bench_source_reports_des_reference() {
        let r = Simulation::new()
            .bench("gcc", 2_000)
            .predictor(PredictorSpec::table(16))
            .run()
            .unwrap();
        assert_eq!(r.bench.as_deref(), Some("gcc"));
        let des = r.des_cpi.unwrap();
        assert!(des > 0.0);
        // Same coarse sanity band as the table4 tests: the analytical
        // predictor is an approximation, so this only guards against a
        // wrong-reference regression (err is a fraction, 5.0 = 500%).
        assert!(r.cpi_error().unwrap() < 5.0);
        assert_eq!(r.predictor, "table");
    }
}
