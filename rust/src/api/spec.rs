//! [`PredictorSpec`] — the one way to say which latency predictor a run
//! should use.
//!
//! Replaces the two historical per-layer predictor enums (one in
//! `reports`, one in `coordinator::pool`) that every caller had to
//! convert between by hand. The spec is plain data (`Clone + Send`), so
//! it can be stored in option structs, shipped across threads, and built
//! into a live [`LatencyPredictor`] any number of times.
//!
//! The two ML backends (`Ml` = PJRT, `Native` = pure Rust) share one
//! [`WeightsSource`] for weight resolution and one [`Backend`] switch
//! ([`PredictorSpec::backend`]) to move a spec between them — so CLI
//! flags, reports, and benches select the backend without re-deriving
//! artifact paths or weight rules.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::predictor::{LatencyPredictor, MlPredictor, NativePredictor, TablePredictor};

pub use crate::predictor::{export_name, WeightsSource};

/// Which ML inference backend a spec builds
/// ([`PredictorSpec::backend`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled PJRT executables (`PredictorSpec::Ml`).
    Pjrt,
    /// Pure-Rust in-process forward pass (`PredictorSpec::Native`).
    Native,
}

/// Which predictor backs a simulation run.
///
/// # Examples
///
/// ```
/// use simnet::api::PredictorSpec;
/// use simnet::predictor::LatencyPredictor;
///
/// // Analytical table predictor: artifact-free, deterministic.
/// let table = PredictorSpec::table(16);
/// assert_eq!(table.label(), "table");
/// assert_eq!(table.build()?.seq_len(), 16);
///
/// // Native pure-Rust backend. With no artifacts on disk, Auto weight
/// // resolution falls back to deterministic generated init weights.
/// let native = PredictorSpec::native("artifacts", "fc2", 8);
/// assert_eq!(native.label(), "native:fc2");
/// let p = native.build()?;
/// assert_eq!(p.seq_len(), 8);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone)]
pub enum PredictorSpec {
    /// AOT-compiled model from the artifacts directory. `model` is the
    /// trained *tag* (e.g. `c3_rob`); the exported HLO is resolved from
    /// its base architecture ([`export_name`]) at build time, so the tag
    /// survives as the spec's identity (the §5 ROB sweep keys
    /// conditioning off it). Weights resolve per [`WeightsSource`].
    Ml { artifacts: PathBuf, model: String, weights: WeightsSource },
    /// Pure-Rust in-process inference over the same `.smw` weights — no
    /// PJRT runtime. `seq` is the fallback sequence length used only when
    /// no `<base>.export` manifest exists in `artifacts` (artifact-free
    /// runs on generated init weights).
    Native { artifacts: PathBuf, model: String, weights: WeightsSource, seq: usize },
    /// Deterministic analytical fallback (runs without artifacts; used by
    /// tests, benches, and ablations).
    Table { seq: usize },
}

impl PredictorSpec {
    /// Analytical table predictor with `seq` context slots.
    pub fn table(seq: usize) -> Self {
        PredictorSpec::Table { seq }
    }

    /// PJRT ML predictor for a trained model tag; weights resolve
    /// automatically ([`WeightsSource::Auto`]).
    pub fn ml(artifacts: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        PredictorSpec::Ml {
            artifacts: artifacts.into(),
            model: model.into(),
            weights: WeightsSource::Auto,
        }
    }

    /// PJRT ML predictor from a *model tag* (e.g. `c3_reg`).
    ///
    /// A user-supplied `explicit_weights` path is kept verbatim, so
    /// [`validate`](Self::validate) / [`build`](Self::build) error out
    /// naming the path when it does not exist — never a silent fallback
    /// to init weights (which is what the pre-API CLI did with
    /// `--weights`). Without one, weights resolve automatically
    /// (`<tag>.smw` when present, else the base model's defaults).
    pub fn ml_tag(artifacts: &Path, tag: &str, explicit_weights: Option<PathBuf>) -> Self {
        let weights = match explicit_weights {
            Some(p) => WeightsSource::Path(p),
            None => WeightsSource::Auto,
        };
        PredictorSpec::Ml { artifacts: artifacts.to_path_buf(), model: tag.to_string(), weights }
    }

    /// Native-backend predictor for a model tag. `fallback_seq` applies
    /// only when `artifacts` has no `<base>.export` manifest.
    pub fn native(
        artifacts: impl Into<PathBuf>,
        model: impl Into<String>,
        fallback_seq: usize,
    ) -> Self {
        PredictorSpec::Native {
            artifacts: artifacts.into(),
            model: model.into(),
            weights: WeightsSource::Auto,
            seq: fallback_seq,
        }
    }

    /// Replace the weights with an explicit path (validated by
    /// [`build`](Self::build), uniformly across both ML backends).
    ///
    /// # Panics
    /// On a [`PredictorSpec::Table`] spec: the table predictor has no
    /// weights, and silently dropping a caller's weights path is exactly
    /// the misconfiguration class this type exists to eliminate.
    pub fn with_weights(self, path: impl Into<PathBuf>) -> Self {
        self.with_weights_source(WeightsSource::Path(path.into()))
    }

    /// Replace the full [`WeightsSource`] (auto / explicit path / init).
    ///
    /// # Panics
    /// On a [`PredictorSpec::Table`] spec, as
    /// [`with_weights`](Self::with_weights).
    pub fn with_weights_source(mut self, source: WeightsSource) -> Self {
        match &mut self {
            PredictorSpec::Ml { weights, .. } | PredictorSpec::Native { weights, .. } => {
                *weights = source
            }
            PredictorSpec::Table { .. } => {
                panic!("with_weights only applies to ML predictor specs")
            }
        }
        self
    }

    /// Move the spec to the given ML inference backend, keeping
    /// artifacts, model tag, and weights source. Converting to `Native`
    /// uses fallback sequence length 32 (only consulted without an
    /// `.export` manifest); converting to `Pjrt` drops the fallback.
    ///
    /// # Panics
    /// On a [`PredictorSpec::Table`] spec: the table predictor is not an
    /// ML backend, and silently ignoring the requested backend is the
    /// misconfiguration class this type exists to eliminate.
    pub fn backend(self, backend: Backend) -> Self {
        match (self, backend) {
            (PredictorSpec::Ml { artifacts, model, weights }, Backend::Native) => {
                PredictorSpec::Native { artifacts, model, weights, seq: 32 }
            }
            (PredictorSpec::Native { artifacts, model, weights, .. }, Backend::Pjrt) => {
                PredictorSpec::Ml { artifacts, model, weights }
            }
            (spec @ (PredictorSpec::Ml { .. } | PredictorSpec::Native { .. }), _) => spec,
            (PredictorSpec::Table { .. }, _) => {
                panic!("backend only applies to ML predictor specs")
            }
        }
    }

    /// Check the spec without constructing a predictor: an explicit
    /// weights path must exist (both ML backends, same error), a native
    /// model must be a supported architecture, and a table predictor
    /// needs at least one slot.
    pub fn validate(&self) -> Result<()> {
        match self {
            PredictorSpec::Ml { weights, .. } => validate_weights(weights),
            PredictorSpec::Native { model, weights, .. } => {
                crate::predictor::native::Arch::parse(&export_name(model))?;
                validate_weights(weights)
            }
            PredictorSpec::Table { seq: 0 } => bail!("table predictor needs seq >= 1"),
            PredictorSpec::Table { .. } => Ok(()),
        }
    }

    /// Construct the live predictor this spec describes.
    pub fn build(&self) -> Result<Box<dyn LatencyPredictor>> {
        self.validate()?;
        Ok(match self {
            PredictorSpec::Ml { artifacts, model, weights } => {
                let base = export_name(model);
                let path = match weights {
                    WeightsSource::Path(p) => Some(p.clone()),
                    // The tag's own trained weights win when present;
                    // otherwise ModelBank resolves the base defaults.
                    WeightsSource::Auto => {
                        Some(artifacts.join(format!("{model}.smw"))).filter(|p| p.exists())
                    }
                    WeightsSource::Init => Some(artifacts.join(format!("{base}.init.smw"))),
                };
                Box::new(MlPredictor::load(artifacts, &base, path.as_deref())?)
            }
            PredictorSpec::Native { artifacts, model, weights, seq } => {
                Box::new(NativePredictor::load(artifacts, model, weights, *seq)?)
            }
            PredictorSpec::Table { seq } => Box::new(TablePredictor::new(*seq)),
        })
    }

    /// Short human-readable name (report column headers, CLI output).
    /// Native specs are prefixed `native:` so reports and the `--json`
    /// output identify the backend; the tag itself survives verbatim
    /// (the §5 ROB sweep keys conditioning off it).
    pub fn label(&self) -> String {
        match self {
            PredictorSpec::Ml { model, .. } => model.clone(),
            PredictorSpec::Native { model, .. } => format!("native:{model}"),
            PredictorSpec::Table { .. } => "table".into(),
        }
    }
}

/// The uniform explicit-path rule shared by both ML backends.
fn validate_weights(weights: &WeightsSource) -> Result<()> {
    match weights {
        WeightsSource::Path(p) if !p.exists() => {
            bail!("weights file {} does not exist", p.display())
        }
        _ => Ok(()),
    }
}

// The spec must stay shippable to worker threads and storable in option
// structs — compile-time guarantee, not a doc promise.
const _: fn() = || {
    fn assert_send_clone<T: Send + Clone>() {}
    assert_send_clone::<PredictorSpec>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_name_strips_suffixes() {
        assert_eq!(export_name("c3"), "c3");
        assert_eq!(export_name("c3_reg"), "c3");
        assert_eq!(export_name("ithemal_lstm2"), "ithemal_lstm2");
        assert_eq!(export_name("lstm2"), "lstm2");
        assert_eq!(export_name("rb_big"), "rb");
    }

    #[test]
    fn explicit_missing_weights_is_an_error_on_both_backends() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let missing = dir.join("no_such.smw");
        // Whether set at construction or after the fact, PJRT or native,
        // a named weights file that does not exist fails validate/build
        // naming the path — never a silent fallback to init weights.
        for spec in [
            PredictorSpec::ml_tag(&dir, "c3", Some(missing.clone())),
            PredictorSpec::ml(&dir, "c3").with_weights(&missing),
            PredictorSpec::native(&dir, "c3", 8).with_weights(&missing),
            PredictorSpec::ml(&dir, "c3").with_weights(&missing).backend(Backend::Native),
        ] {
            let err = spec.validate().unwrap_err();
            assert!(err.to_string().contains("no_such.smw"), "err: {err}");
            assert!(spec.build().is_err());
        }
    }

    #[test]
    fn absent_default_weights_resolve_to_auto() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let spec = PredictorSpec::ml_tag(&dir, "c3", None);
        match spec {
            PredictorSpec::Ml { weights, model, .. } => {
                assert_eq!(model, "c3");
                assert_eq!(weights, WeightsSource::Auto);
            }
            other => panic!("unexpected spec {other:?}"),
        }
    }

    #[test]
    fn ml_tag_keeps_tag_as_label() {
        // The §5 ROB sweep keys conditioning off the tag ("c3_rob"), so
        // the label must NOT collapse to the exported base architecture.
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let spec = PredictorSpec::ml_tag(&dir, "c3_rob", None);
        assert_eq!(spec.label(), "c3_rob");
        assert_eq!(export_name("c3_rob"), "c3");
        // Same invariant on the native backend: the tag survives in the
        // label behind the backend prefix.
        assert!(spec.backend(Backend::Native).label().contains("c3_rob"));
    }

    #[test]
    fn backend_switch_roundtrips() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let native = PredictorSpec::ml(&dir, "c3_reg").backend(Backend::Native);
        assert_eq!(native.label(), "native:c3_reg");
        match &native {
            PredictorSpec::Native { model, weights, .. } => {
                assert_eq!(model, "c3_reg");
                assert_eq!(*weights, WeightsSource::Auto);
            }
            other => panic!("unexpected spec {other:?}"),
        }
        let back = native.backend(Backend::Pjrt);
        assert_eq!(back.label(), "c3_reg");
        assert!(matches!(back, PredictorSpec::Ml { .. }));
        // Re-selecting the current backend is a no-op, not an error.
        assert!(matches!(back.backend(Backend::Pjrt), PredictorSpec::Ml { .. }));
    }

    #[test]
    fn native_spec_validates_architecture() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let err = PredictorSpec::native(&dir, "lstm2", 8).validate().unwrap_err();
        assert!(err.to_string().contains("PJRT"), "err: {err}");
        assert!(PredictorSpec::native(&dir, "c3_rob", 8).validate().is_ok());
    }

    #[test]
    fn native_spec_builds_from_init_without_artifacts() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let spec = PredictorSpec::native(&dir, "fc2", 8);
        assert_eq!(spec.label(), "native:fc2");
        let p = spec.build().unwrap();
        assert_eq!(p.seq_len(), 8);
    }

    #[test]
    fn table_spec_builds_and_labels() {
        let spec = PredictorSpec::table(16);
        assert_eq!(spec.label(), "table");
        let p = spec.build().unwrap();
        assert_eq!(p.seq_len(), 16);
        assert!(PredictorSpec::table(0).build().is_err());
    }
}
