//! [`PredictorSpec`] — the one way to say which latency predictor a run
//! should use.
//!
//! Replaces the two historical per-layer predictor enums (one in
//! `reports`, one in `coordinator::pool`) that every caller had to
//! convert between by hand. The spec is plain data (`Clone + Send`), so
//! it can be stored in
//! option structs, shipped across threads, and built into a live
//! [`LatencyPredictor`] any number of times.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::predictor::{LatencyPredictor, MlPredictor, TablePredictor};

/// Which predictor backs a simulation run.
#[derive(Debug, Clone)]
pub enum PredictorSpec {
    /// AOT-compiled model from the artifacts directory. `model` is the
    /// trained *tag* (e.g. `c3_rob`); the exported HLO is resolved from
    /// its base architecture ([`export_name`]) at build time, so the tag
    /// survives as the spec's identity (the §5 ROB sweep keys
    /// conditioning off it). `weights` is an explicit `.smw` path;
    /// `None` lets the runtime resolve the model's default weights (or
    /// fall back to init weights).
    Ml { artifacts: PathBuf, model: String, weights: Option<PathBuf> },
    /// Deterministic analytical fallback (runs without artifacts; used by
    /// tests, benches, and ablations).
    Table { seq: usize },
}

impl PredictorSpec {
    /// Analytical table predictor with `seq` context slots.
    pub fn table(seq: usize) -> Self {
        PredictorSpec::Table { seq }
    }

    /// ML predictor for a trained model tag; weights resolve to the
    /// runtime default.
    pub fn ml(artifacts: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        PredictorSpec::Ml { artifacts: artifacts.into(), model: model.into(), weights: None }
    }

    /// ML predictor from a *model tag* (e.g. `c3_reg`) with weight
    /// resolution: the weights default to `<artifacts>/<tag>.smw` when
    /// that file exists.
    ///
    /// A user-supplied `explicit_weights` path is kept verbatim, so
    /// [`validate`](Self::validate) / [`build`](Self::build) error out
    /// naming the path when it does not exist — never a silent fallback
    /// to init weights (which is what the pre-API CLI did with
    /// `--weights`).
    pub fn ml_tag(artifacts: &Path, tag: &str, explicit_weights: Option<PathBuf>) -> Self {
        let weights = explicit_weights
            .or_else(|| Some(artifacts.join(format!("{tag}.smw"))).filter(|p| p.exists()));
        PredictorSpec::Ml { artifacts: artifacts.to_path_buf(), model: tag.to_string(), weights }
    }

    /// Replace the weights path (explicit; validated by [`build`](Self::build)).
    ///
    /// # Panics
    /// On a [`PredictorSpec::Table`] spec: the table predictor has no
    /// weights, and silently dropping a caller's weights path is exactly
    /// the misconfiguration class this type exists to eliminate.
    pub fn with_weights(mut self, path: impl Into<PathBuf>) -> Self {
        match &mut self {
            PredictorSpec::Ml { weights, .. } => *weights = Some(path.into()),
            PredictorSpec::Table { .. } => {
                panic!("with_weights only applies to ML predictor specs")
            }
        }
        self
    }

    /// Check the spec without constructing a predictor: a named weights
    /// file must exist, and a table predictor needs at least one slot.
    pub fn validate(&self) -> Result<()> {
        match self {
            PredictorSpec::Ml { weights: Some(p), .. } if !p.exists() => {
                bail!("weights file {} does not exist", p.display())
            }
            PredictorSpec::Table { seq: 0 } => bail!("table predictor needs seq >= 1"),
            _ => Ok(()),
        }
    }

    /// Construct the live predictor this spec describes.
    pub fn build(&self) -> Result<Box<dyn LatencyPredictor>> {
        self.validate()?;
        Ok(match self {
            PredictorSpec::Ml { artifacts, model, weights } => {
                Box::new(MlPredictor::load(artifacts, &export_name(model), weights.as_deref())?)
            }
            PredictorSpec::Table { seq } => Box::new(TablePredictor::new(*seq)),
        })
    }

    /// Short human-readable name (report column headers, CLI output).
    pub fn label(&self) -> String {
        match self {
            PredictorSpec::Ml { model, .. } => model.clone(),
            PredictorSpec::Table { .. } => "table".into(),
        }
    }
}

/// Map a trained model *tag* to the architecture name its exported HLO is
/// stored under: tags may carry suffixes (e.g. `c3_reg`, `c3_big`) while
/// sharing the export of their base architecture.
pub fn export_name(tag: &str) -> String {
    for base in ["ithemal_lstm2", "lstm2", "fc2", "fc3", "c1", "c3", "rb", "tx2"] {
        if tag == base || tag.starts_with(&format!("{base}_")) {
            return base.to_string();
        }
    }
    tag.to_string()
}

// The spec must stay shippable to worker threads and storable in option
// structs — compile-time guarantee, not a doc promise.
const _: fn() = || {
    fn assert_send_clone<T: Send + Clone>() {}
    assert_send_clone::<PredictorSpec>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_name_strips_suffixes() {
        assert_eq!(export_name("c3"), "c3");
        assert_eq!(export_name("c3_reg"), "c3");
        assert_eq!(export_name("ithemal_lstm2"), "ithemal_lstm2");
        assert_eq!(export_name("lstm2"), "lstm2");
        assert_eq!(export_name("rb_big"), "rb");
    }

    #[test]
    fn explicit_missing_weights_is_an_error() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let missing = dir.join("no_such.smw");
        // Whether set at construction or after the fact, a named weights
        // file that does not exist fails validate/build naming the path.
        for spec in [
            PredictorSpec::ml_tag(&dir, "c3", Some(missing.clone())),
            PredictorSpec::ml(&dir, "c3").with_weights(&missing),
        ] {
            let err = spec.validate().unwrap_err();
            assert!(err.to_string().contains("no_such.smw"), "err: {err}");
            assert!(spec.build().is_err());
        }
    }

    #[test]
    fn absent_default_weights_resolve_to_none() {
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let spec = PredictorSpec::ml_tag(&dir, "c3", None);
        match spec {
            PredictorSpec::Ml { weights, model, .. } => {
                assert_eq!(model, "c3");
                assert!(weights.is_none());
            }
            other => panic!("unexpected spec {other:?}"),
        }
    }

    #[test]
    fn ml_tag_keeps_tag_as_label() {
        // The §5 ROB sweep keys conditioning off the tag ("c3_rob"), so
        // the label must NOT collapse to the exported base architecture.
        let dir = std::env::temp_dir().join("simnet_spec_nothing_here");
        let spec = PredictorSpec::ml_tag(&dir, "c3_rob", None);
        assert_eq!(spec.label(), "c3_rob");
        assert_eq!(export_name("c3_rob"), "c3");
    }

    #[test]
    fn table_spec_builds_and_labels() {
        let spec = PredictorSpec::table(16);
        assert_eq!(spec.label(), "table");
        let p = spec.build().unwrap();
        assert_eq!(p.seq_len(), 16);
        assert!(PredictorSpec::table(0).build().is_err());
    }
}
