//! [`SimReport`] — the one machine-readable result of a [`super::Simulation`]
//! run, whatever execution mode produced it.
//!
//! JSON serialization is hand-rolled (serde is not vendored in this
//! image): [`SimReport::to_json`] emits one pretty-printed object, and
//! [`SimReport::json_fields`] exposes the same key/value pairs as
//! already-rendered JSON fragments so other writers (e.g.
//! `benches/bench_engine.rs`) can embed a report inside their own
//! top-level objects without duplicating the format.

use crate::coordinator::{EngineStats, SimOutcome};
use crate::trace::InputStats;

/// How [`super::Simulation::run`] executed the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One instruction at a time (paper §3.2).
    Sequential,
    /// Sub-trace parallel over the shared [`crate::coordinator::BatchEngine`] (§3.3).
    Engine,
    /// Multi-job pooling: trace sharded over workers, one shared engine (§3.3/Fig. 9).
    Pool,
}

impl ExecMode {
    /// Stable lowercase name used in report output and JSON
    /// (`"sequential"`, `"engine"`, `"pool"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Engine => "engine",
            ExecMode::Pool => "pool",
        }
    }
}

/// Unified result of an ML-simulation run: the merged [`SimOutcome`],
/// the engine's batching statistics when an engine ran, the predictor
/// label, and the DES-reference CPI when one is known.
///
/// # Examples
///
/// ```
/// use simnet::api::{PredictorSpec, Simulation};
///
/// let report = Simulation::new()
///     .bench("xz", 1_000)
///     .predictor(PredictorSpec::table(8))
///     .run()?;
/// assert!(report.cpi() > 0.0);
/// assert!(report.cpi_error().is_some(), "bench sources carry a DES reference");
/// let json = report.to_json();
/// assert!(json.contains("\"schema\": \"simnet.sim_report/v1\""));
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Predictor label ([`super::PredictorSpec::label`], or the label
    /// given to a borrowed predictor).
    pub predictor: String,
    /// Execution mode [`super::Simulation::run`] selected.
    pub mode: ExecMode,
    /// Benchmark name when the input came from `.bench(..)`.
    pub bench: Option<String>,
    /// Machine configuration name (`SimConfig::name`).
    pub config: String,
    /// Merged simulation outcome (instructions, cycles, windows, wall).
    pub outcome: SimOutcome,
    /// Batching statistics (engine and pool modes; `None` for sequential).
    pub engine: Option<EngineStats>,
    /// Reference CPI: the DES's when the input was a benchmark, the
    /// trace's own fetch-latency CPI when the input was a trace.
    pub des_cpi: Option<f64>,
    /// Input byte accounting: bytes served zero-copy through the mmap
    /// path vs staged through buffered `read` copies (both zero for
    /// in-memory and bench sources), plus the streaming residency bound
    /// (`peak_resident_records` / `window_records`).
    pub input: InputStats,
}

impl SimReport {
    /// Simulated cycles per instruction.
    pub fn cpi(&self) -> f64 {
        self.outcome.cpi()
    }

    /// Simulation throughput in million instructions per second.
    pub fn mips(&self) -> f64 {
        self.outcome.mips()
    }

    /// Relative CPI error against the reference, when one is known.
    pub fn cpi_error(&self) -> Option<f64> {
        self.des_cpi.map(|des| crate::stats::cpi_error(self.cpi(), des))
    }

    /// The report's key/value pairs, values pre-rendered as JSON
    /// fragments, in emission order. Shared by [`to_json`](Self::to_json)
    /// and external writers that embed reports in larger objects.
    pub fn json_fields(&self) -> Vec<(&'static str, String)> {
        let mut fields: Vec<(&'static str, String)> = vec![
            ("schema", json_str("simnet.sim_report/v1")),
            ("predictor", json_str(&self.predictor)),
            ("mode", json_str(self.mode.as_str())),
            ("bench", self.bench.as_deref().map(json_str).unwrap_or_else(|| "null".into())),
            ("config", json_str(&self.config)),
            ("instructions", self.outcome.instructions.to_string()),
            ("cycles", self.outcome.cycles.to_string()),
            ("inferences", self.outcome.inferences.to_string()),
            ("cpi", json_f(self.cpi())),
            ("des_cpi", self.des_cpi.map(json_f).unwrap_or_else(|| "null".into())),
            (
                "cpi_err_pct",
                self.cpi_error().map(|e| json_f(e * 100.0)).unwrap_or_else(|| "null".into()),
            ),
            ("mips", json_f(self.mips())),
            ("wall_seconds", json_f(self.outcome.wall_seconds)),
            ("bytes_mapped", self.input.bytes_mapped.to_string()),
            ("bytes_copied", self.input.bytes_copied.to_string()),
            ("peak_resident_records", self.input.peak_resident_records.to_string()),
            ("window_records", self.input.window_records.to_string()),
        ];
        let windows: Vec<String> =
            self.outcome.windows.iter().map(|(n, c)| format!("[{n}, {c}]")).collect();
        fields.push(("windows", format!("[{}]", windows.join(", "))));
        fields.push((
            "engine",
            match &self.engine {
                None => "null".into(),
                Some(s) => format!(
                    "{{\"batches\": {}, \"slots\": {}, \"target_batch\": {}, \
                     \"starved\": {}, \"filled\": {}, \"subtraces\": {}, \
                     \"encode_threads\": {}, \"pipeline_depth\": {}, \
                     \"mean_occupancy\": {}, \"fill\": {}, \"predictor_idle\": {}, \
                     \"encode_seconds\": {}, \"predict_seconds\": {}, \
                     \"engine_seconds\": {}}}",
                    s.batches,
                    s.slots,
                    s.target_batch,
                    s.starved,
                    s.filled,
                    s.subtraces,
                    s.encode_threads,
                    s.pipeline_depth,
                    json_f(s.mean_occupancy()),
                    json_f(s.fill_ratio()),
                    json_f(s.predictor_idle()),
                    json_f(s.encode_seconds),
                    json_f(s.predict_seconds),
                    json_f(s.engine_seconds),
                ),
            },
        ));
        fields
    }

    /// Render the report as one single-line JSON object — same fields
    /// and values as [`to_json`](Self::to_json), no newlines. The job
    /// server's wire protocol is newline-delimited, so embedded reports
    /// use this form.
    pub fn to_json_compact(&self) -> String {
        let fields = self.json_fields();
        let body: Vec<String> = fields.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        format!("{{{}}}", body.join(", "))
    }

    /// Render the report as one pretty-printed JSON object.
    pub fn to_json(&self) -> String {
        let fields = self.json_fields();
        let mut s = String::from("{\n");
        for (i, (k, v)) in fields.iter().enumerate() {
            let comma = if i + 1 < fields.len() { "," } else { "" };
            s.push_str(&format!("  \"{k}\": {v}{comma}\n"));
        }
        s.push_str("}\n");
        s
    }
}

/// Render a float as a JSON number with a stable, parseable format.
fn json_f(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".into()
    }
}

/// Render a string as a JSON string literal (escaping the characters a
/// model tag / bench name / path could plausibly contain).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes_strings() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn non_finite_floats_become_null() {
        assert_eq!(json_f(f64::NAN), "null");
        assert_eq!(json_f(f64::INFINITY), "null");
        assert_eq!(json_f(1.5), "1.500000");
    }
}
