//! Minimal, dependency-free stand-in for the `anyhow` crate so the
//! workspace builds with no network access. It implements exactly the
//! subset SimNet uses: [`Error`], [`Result`], the [`anyhow!`] and
//! [`bail!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`. Context is flattened into the message eagerly instead
//! of kept as a source chain — good enough for CLI/test diagnostics.

use std::fmt;

/// A string-message error. Context layers are prepended `"{ctx}: {msg}"`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, which is
// what keeps this blanket conversion coherent (same trick as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        Error { msg: err.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format_args!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format_args!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built as in [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse().context("bad number")?;
        if v > 100 {
            bail!("too big: {v}");
        }
        Ok(v)
    }

    #[test]
    fn conversion_and_context() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("bad number: "), "{e}");
        let e = parse("101").unwrap_err();
        assert_eq!(e.to_string(), "too big: 101");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let err: std::result::Result<(), std::io::Error> = Err(std::io::Error::other("boom"));
        let e = err.with_context(|| format!("ctx {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "ctx 1: boom");
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x={}", 3).to_string(), "x=3");
        let who = "y";
        assert_eq!(anyhow!("inline {who}").to_string(), "inline y");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }
}
