//! Offline stub of the `xla` crate (PJRT bindings over xla_extension).
//!
//! The real backend cannot be vendored in this image, so every entry
//! point returns a runtime error: `MlPredictor::load` fails soft with a
//! clear message while the table-predictor paths — and the whole build,
//! test, and bench pipeline — stay green. To enable real PJRT execution,
//! point the `xla` dependency in `rust/Cargo.toml` at the actual crate
//! (`xla` over xla_extension 0.5.1); the API surface below mirrors it.

use std::fmt;

/// Error type matching how SimNet formats PJRT failures (`{e:?}`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!("{what}: PJRT backend not available (xla stub build)")))
}

/// Stub of a PJRT client (the real one owns a CPU/GPU device).
pub struct PjRtClient;

/// Stub of a device-resident buffer.
pub struct PjRtBuffer;

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto;

/// Stub of an XLA computation built from an HLO proto.
pub struct XlaComputation;

/// Stub of a host-side literal value.
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_soft_with_clear_message() {
        let Err(err) = PjRtClient::cpu() else { panic!("stub must not succeed") };
        assert!(format!("{err:?}").contains("PJRT backend not available"));
    }
}
