//! Phase-level CPI analysis (paper Figure 6): windowed CPI curves from the
//! DES and from SimNet side by side, as terminal sparklines.
//!
//! Usage: cargo run --release --example phase_analysis [-- <bench> <n> <window>]

use std::path::Path;

use simnet::coordinator::simulate_sequential;
use simnet::des::{simulate, SimConfig};
use simnet::predictor::{LatencyPredictor, MlPredictor, TablePredictor};
use simnet::stats::render_cpi_series;
use simnet::trace::TraceRecord;
use simnet::workload::find;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bench = args.first().map(|s| s.as_str()).unwrap_or("bwaves"); // phased benchmark
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60_000);
    let window: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1_000);

    let cfg = SimConfig::default_o3();
    let b = find(bench).expect("unknown benchmark");
    let mut recs = Vec::new();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));

    // DES windowed CPI from the per-instruction fetch latencies.
    let mut des_windows = Vec::new();
    let (mut acc, mut cnt) = (0u64, 0u64);
    for r in &recs {
        acc += r.f_lat as u64;
        cnt += 1;
        if cnt == window {
            des_windows.push((cnt, acc));
            acc = 0;
            cnt = 0;
        }
    }

    let mut predictor: Box<dyn LatencyPredictor> =
        match MlPredictor::load(Path::new("artifacts"), "c3", None) {
            Ok(p) => Box::new(p),
            Err(_) => Box::new(TablePredictor::new(32)),
        };
    let out = simulate_sequential(&recs, &cfg, predictor.as_mut(), window)?;

    println!("=== {bench}: CPI per {window}-instruction window ===\n");
    print!("{}", render_cpi_series("des   ", &des_windows));
    print!("{}", render_cpi_series("simnet", &out.windows));

    // Phase-tracking score: correlation of the two window series.
    let d: Vec<f64> =
        des_windows.iter().map(|(n, c)| *c as f64 / (*n).max(1) as f64).collect();
    let s: Vec<f64> = out.windows.iter().map(|(n, c)| *c as f64 / (*n).max(1) as f64).collect();
    let k = d.len().min(s.len());
    let (dm, sm) = (mean(&d[..k]), mean(&s[..k]));
    let cov: f64 = (0..k).map(|i| (d[i] - dm) * (s[i] - sm)).sum::<f64>() / k as f64;
    let (dv, sv) = (var(&d[..k], dm), var(&s[..k], sm));
    let corr = cov / (dv.sqrt() * sv.sqrt()).max(1e-12);
    println!("\nwindow-CPI correlation (des vs simnet): {corr:.3}");
    Ok(())
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

fn var(xs: &[f64], m: f64) -> f64 {
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len().max(1) as f64
}
