//! Design-space exploration (paper §5): compare L2 cache sizes with the
//! reference DES and with SimNet, reporting *relative* accuracy — the
//! metric architects actually use when no hardware exists to validate
//! against.
//!
//! Usage: cargo run --release --example design_space [-- <n-per-bench>]

use std::path::Path;

use simnet::coordinator::simulate_sequential;
use simnet::des::{simulate, SimConfig};
use simnet::predictor::{LatencyPredictor, MlPredictor, TablePredictor};
use simnet::stats::{speedup_pct, Table};
use simnet::trace::TraceRecord;
use simnet::workload::find;

const BENCHES: [&str; 3] = ["mcf", "xalancbmk", "lbm"];
const L2_KB: [u64; 4] = [256, 512, 1024, 4096];

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let mut predictor: Box<dyn LatencyPredictor> =
        match MlPredictor::load(Path::new("artifacts"), "c3", None) {
            Ok(p) => Box::new(p),
            Err(_) => {
                println!("(artifacts missing; using analytical TablePredictor)");
                Box::new(TablePredictor::new(32))
            }
        };

    println!("=== L2 size exploration: {} instructions x {:?} ===\n", n, BENCHES);
    let mut table = Table::new(&["l2", "des_cycles", "sim_cycles", "des_speedup", "sim_speedup"]);
    let mut base: Option<(u64, u64)> = None;
    for kb in L2_KB {
        let mut cfg = SimConfig::default_o3();
        cfg.l2.size = kb << 10;
        let mut des_total = 0u64;
        let mut sim_total = 0u64;
        for bench in BENCHES {
            let b = find(bench).unwrap();
            let mut recs = Vec::new();
            let des = simulate(&cfg, b.workload(1).stream(), n, |e| {
                recs.push(TraceRecord::from(e));
            });
            let out = simulate_sequential(&recs, &cfg, predictor.as_mut(), 0)?;
            des_total += des.cycles;
            sim_total += out.cycles;
        }
        let (bd, bs) = *base.get_or_insert((des_total, sim_total));
        table.row(vec![
            format!("{kb}KB"),
            des_total.to_string(),
            sim_total.to_string(),
            format!("{:+.2}%", speedup_pct(bd, des_total)),
            format!("{:+.2}%", speedup_pct(bs, sim_total)),
        ]);
    }
    print!("{}", table.render());
    println!("\nThe two speedup columns should track each other (relative accuracy).");
    Ok(())
}
