//! Parallel-simulation throughput demo (paper §3.3 / Figures 8-9): how
//! sub-trace batching and worker streams turn an inherently sequential
//! prediction chain into accelerator-sized batches.
//!
//! Usage: cargo run --release --example parallel_throughput [-- <n>]

use std::path::Path;

use simnet::coordinator::pool::PoolPredictor;
use simnet::coordinator::{simulate_parallel, simulate_pool_report, PoolOptions};
use simnet::des::{simulate, SimConfig};
use simnet::predictor::{LatencyPredictor, MlPredictor, TablePredictor};
use simnet::stats::Table;
use simnet::trace::TraceRecord;
use simnet::workload::find;

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let cfg = SimConfig::default_o3();
    let b = find("xz").unwrap();
    let mut recs = Vec::new();
    let t0 = std::time::Instant::now();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));
    let des_mips = n as f64 / t0.elapsed().as_secs_f64() / 1e6;

    let artifacts = Path::new("artifacts");
    let have_artifacts = artifacts.join("c3.export").exists();
    let mut predictor: Box<dyn LatencyPredictor> = if have_artifacts {
        Box::new(MlPredictor::load(artifacts, "c3", None)?)
    } else {
        println!("(artifacts missing; using analytical TablePredictor)");
        Box::new(TablePredictor::new(32))
    };

    println!("=== sub-trace scaling (single worker) ===");
    let mut t = Table::new(&["subtraces", "MIPS", "cpi"]);
    for subs in [1usize, 8, 64, 256, 1024] {
        let out = simulate_parallel(&recs, &cfg, predictor.as_mut(), subs, 0)?;
        t.row(vec![subs.to_string(), format!("{:.3}", out.mips()), format!("{:.3}", out.cpi())]);
    }
    print!("{}", t.render());

    println!("\n=== shared-engine scaling (256 sub-traces per job, 4 encode threads) ===");
    let pool_pred = if have_artifacts {
        PoolPredictor::Ml { artifacts: artifacts.to_path_buf(), model: "c3".into(), weights: None }
    } else {
        PoolPredictor::Table { seq: 32 }
    };
    let mut t = Table::new(&["jobs", "MIPS", "speedup_vs_des", "batch_occupancy"]);
    for w in [1usize, 2, 4] {
        let opts = PoolOptions {
            workers: w,
            subtraces: 256 * w,
            predictor: pool_pred.clone(),
            window: 0,
            // A bounded target gives several batches per round, which is
            // what lets pipeline_depth 2 overlap encode with predict.
            target_batch: 128,
            encode_threads: 4,
            pipeline_depth: 2,
        };
        let (out, stats) = simulate_pool_report(&recs, &cfg, &opts)?;
        t.row(vec![
            w.to_string(),
            format!("{:.3}", out.mips()),
            format!("{:.2}x", out.mips() / des_mips),
            format!("{:.1}", stats.mean_occupancy()),
        ]);
    }
    print!("{}", t.render());
    println!("\ndes reference: {des_mips:.3} MIPS");
    Ok(())
}
