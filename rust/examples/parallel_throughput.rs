//! Parallel-simulation throughput demo (paper §3.3 / Figures 8-9): how
//! sub-trace batching and worker streams turn an inherently sequential
//! prediction chain into accelerator-sized batches.
//!
//! Everything runs through the unified `simnet::api::Simulation` builder:
//! the same session, re-run with different knobs, walks from sequential
//! to sub-trace parallel to multi-job pooled execution.
//!
//! Usage: cargo run --release --example parallel_throughput [-- <n>]

use std::path::Path;

use simnet::api::{PredictorSpec, Simulation};
use simnet::coordinator::EngineOptions;
use simnet::des::{simulate, SimConfig};
use simnet::stats::Table;
use simnet::trace::TraceRecord;
use simnet::workload::find;

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let cfg = SimConfig::default_o3();
    let b = find("xz").unwrap();
    let mut recs = Vec::new();
    let t0 = std::time::Instant::now();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));
    let des_mips = n as f64 / t0.elapsed().as_secs_f64() / 1e6;

    let artifacts = Path::new("artifacts");
    let spec = if artifacts.join("c3.export").exists() {
        PredictorSpec::ml(artifacts, "c3")
    } else {
        println!("(artifacts missing; using analytical TablePredictor)");
        PredictorSpec::table(32)
    };
    let mut predictor = spec.build()?;

    println!("=== sub-trace scaling (single worker) ===");
    let mut t = Table::new(&["subtraces", "MIPS", "cpi"]);
    for subs in [1usize, 8, 64, 256, 1024] {
        let out = Simulation::new()
            .records(&recs)
            .config(&cfg)
            .predictor_ref(predictor.as_mut())
            .subtraces(subs)
            .run()?;
        t.row(vec![subs.to_string(), format!("{:.3}", out.mips()), format!("{:.3}", out.cpi())]);
    }
    print!("{}", t.render());

    println!("\n=== shared-engine scaling (256 sub-traces per job, 4 encode threads) ===");
    let mut t = Table::new(&["jobs", "MIPS", "speedup_vs_des", "batch_occupancy"]);
    for w in [1usize, 2, 4] {
        let report = Simulation::new()
            .records(&recs)
            .config(&cfg)
            .predictor_ref(predictor.as_mut())
            .workers(w)
            .subtraces(256 * w)
            // A bounded target gives several batches per round, which is
            // what lets pipeline_depth 2 overlap encode with predict.
            .engine(EngineOptions {
                target_batch: 128,
                encode_threads: 4,
                pipeline_depth: 2,
                fork_predict: true,
            })
            .run()?;
        let occupancy = report.engine.as_ref().map(|s| s.mean_occupancy()).unwrap_or(0.0);
        t.row(vec![
            w.to_string(),
            format!("{:.3}", report.mips()),
            format!("{:.2}x", report.mips() / des_mips),
            format!("{occupancy:.1}"),
        ]);
    }
    print!("{}", t.render());
    println!("\ndes reference: {des_mips:.3} MIPS");
    Ok(())
}
