//! Quickstart — the end-to-end driver.
//!
//! Exercises the full three-layer stack on a real small workload:
//!
//! 1. synthesize a benchmark's dynamic instruction stream (workload gen),
//! 2. run the reference DES over it (the gem5-substitute teacher),
//! 3. ML-simulate the same trace with the AOT-compiled Pallas/JAX model
//!    through the rust PJRT runtime — sequentially and sub-trace-parallel,
//! 4. report the headline metrics: CPI error vs the DES and simulation
//!    throughput (MIPS), i.e. the paper's accuracy/performance trade.
//!
//! Usage: cargo run --release --example quickstart [-- <bench> <n> <model>]
//! Falls back to the analytical TablePredictor when `artifacts/` has not
//! been built yet (run `make artifacts` for the real model).

use std::path::Path;

use simnet::coordinator::{simulate_parallel_with, simulate_sequential, ParallelOptions};
use simnet::des::{simulate, SimConfig};
use simnet::predictor::{LatencyPredictor, MlPredictor, TablePredictor};
use simnet::stats::cpi_error;
use simnet::trace::TraceRecord;
use simnet::workload::find;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bench = args.first().map(|s| s.as_str()).unwrap_or("xalancbmk");
    let n: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30_000);
    let model = args.get(2).map(|s| s.as_str()).unwrap_or("c3");
    let artifacts = Path::new("artifacts");

    println!("=== SimNet quickstart: {bench}, {n} instructions ===\n");

    // 1+2. Workload -> reference DES (teacher + ground truth).
    let cfg = SimConfig::default_o3();
    let b = find(bench).expect("unknown benchmark; try `repro list-benches`");
    let mut records = Vec::new();
    let t0 = std::time::Instant::now();
    let des = simulate(&cfg, b.workload(1).stream(), n, |e| {
        records.push(TraceRecord::from(e));
    });
    let des_wall = t0.elapsed().as_secs_f64();
    println!(
        "[des]  cpi={:.3}  mispredicts={}  l1d_misses={}  ({:.2} MIPS)",
        des.cpi(),
        des.mispredicts,
        des.l1d_miss,
        n as f64 / des_wall / 1e6
    );

    // 3. ML simulation through the AOT artifact (PJRT), if built.
    let mut predictor: Box<dyn LatencyPredictor> =
        match MlPredictor::load(artifacts, model, None) {
            Ok(p) => {
                println!("[ml]   loaded AOT model '{model}' from artifacts/");
                Box::new(p)
            }
            Err(e) => {
                println!("[ml]   artifacts not available ({e}); using TablePredictor");
                Box::new(TablePredictor::new(32))
            }
        };

    let seq = simulate_sequential(&records, &cfg, predictor.as_mut(), 0)?;
    println!(
        "[ml]   sequential: cpi={:.3}  err={:.2}%  ({:.3} MIPS)",
        seq.cpi(),
        cpi_error(seq.cpi(), des.cpi()) * 100.0,
        seq.mips()
    );

    for subs in [16usize, 64, 256] {
        let opts = ParallelOptions { subtraces: subs, ..ParallelOptions::default() };
        let par = simulate_parallel_with((&records[..]).into(), &cfg, predictor.as_mut(), &opts)?;
        println!(
            "[ml]   parallel x{subs:<4}: cpi={:.3}  err={:.2}%  ({:.3} MIPS, {:.1}x vs sequential)",
            par.cpi(),
            cpi_error(par.cpi(), des.cpi()) * 100.0,
            par.mips(),
            par.mips() / seq.mips().max(1e-12)
        );
    }

    println!("\nDone. See `repro report` / `repro sweep` for the paper's full tables.");
    Ok(())
}
