//! Bench: feature-encode throughput — the gather half of the batching
//! engine, isolated from prediction. Replays a DES trace through a warm
//! `ContextTracker` and measures how fast each encoding path turns
//! instructions into `seq x NUM_FEATURES` model inputs:
//!
//! * `encode_legacy_seqS` — per-slot AoS encoding (`encode_input`), one
//!   contiguous 50-float row per timestep, rebuilt from the context
//!   deque every call.
//! * `encode_soa_seqS` — the reusable structure-of-arrays panels
//!   (`SoaBatch::encode_into`) the engine gathers with, interleaved into
//!   the same AoS layout at the end. Bit-identical output (asserted).
//!
//! "MIPS" here is millions of *encoded instructions* per second, so the
//! rows gate on the same scale as the engine bench.
//!
//! Flags / env:
//! * `--quick` (or `SIMNET_BENCH_QUICK=1`) — small trace + trimmed sweep
//!   for the CI bench-smoke job.
//! * `--json PATH` — additionally write the results as JSON
//!   (`BENCH_encode.json` in CI; compared against `bench/baseline.json`
//!   by `scripts/compare_bench.py`).
//! * `SIMNET_BENCH_N` — override the instruction count.

mod common;

use std::fmt::Write as _;
use std::time::Instant;

use simnet::des::{simulate, SimConfig};
use simnet::features::soa::SoaBatch;
use simnet::features::{ContextTracker, NUM_FEATURES};
use simnet::stats::Table;
use simnet::trace::{open_store, TraceRecord, TraceWriter};
use simnet::workload::find;

/// Batch slots cycled through while replaying — matches the engine's
/// panel-reuse pattern (one SoA panel set serving many slots).
const SLOTS: usize = 64;

struct Row {
    name: String,
    seq: usize,
    mips: f64,
}

/// Replay the trace once, encoding every instruction into its batch slot
/// and then retiring it with its recorded latencies (the ground-truth
/// replay the engine performs with predicted latencies). Returns
/// (seconds, checksum); the checksum pins the two paths to each other.
fn replay<F>(recs: &[TraceRecord], cfg: &SimConfig, width: usize, mut encode: F) -> (f64, f64)
where
    F: FnMut(&ContextTracker, &TraceRecord, usize, &mut [f32]),
{
    let mut tracker = ContextTracker::new(cfg);
    let mut batch = vec![0.0f32; SLOTS * width];
    let mut checksum = 0.0f64;
    let t0 = Instant::now();
    for (i, rec) in recs.iter().enumerate() {
        let slot = i % SLOTS;
        let out = &mut batch[slot * width..(slot + 1) * width];
        encode(&tracker, rec, slot, out);
        checksum += (out[0] + out[width - 1]) as f64;
        let s_lat = if rec.inst.is_store() { rec.s_lat.max(rec.e_lat + 1) } else { 0 };
        tracker.push(&rec.inst, &rec.hist, rec.f_lat, rec.e_lat.max(1), s_lat);
    }
    (t0.elapsed().as_secs_f64(), checksum)
}

/// Run both encode paths at one sequence length, `reps` passes each
/// (best-of, to shrug off scheduler noise), and return (legacy, soa).
fn run_seq(recs: &[TraceRecord], cfg: &SimConfig, seq: usize, reps: usize) -> (Row, Row) {
    let width = seq * NUM_FEATURES;
    let n = recs.len() as f64;
    let mips = |secs: f64| n / secs.max(1e-12) / 1e6;

    let mut legacy_best = 0.0f64;
    let mut legacy_sum = 0.0f64;
    for _ in 0..reps {
        let (secs, sum) = replay(recs, cfg, width, |t, rec, _slot, out| {
            t.encode_input(&rec.inst, &rec.hist, seq, out)
        });
        legacy_best = legacy_best.max(mips(secs));
        legacy_sum = sum;
    }

    let mut soa = SoaBatch::new(SLOTS, seq);
    let mut soa_best = 0.0f64;
    let mut soa_sum = 0.0f64;
    for _ in 0..reps {
        let (secs, sum) = replay(recs, cfg, width, |t, rec, slot, out| {
            soa.encode_into(t, &rec.inst, &rec.hist, slot, out)
        });
        soa_best = soa_best.max(mips(secs));
        soa_sum = sum;
    }
    assert_eq!(
        legacy_sum.to_bits(),
        soa_sum.to_bits(),
        "SoA encode must stay bit-identical to legacy at seq {seq}"
    );

    (
        Row { name: format!("encode_legacy_seq{seq}"), seq, mips: legacy_best },
        Row { name: format!("encode_soa_seq{seq}"), seq, mips: soa_best },
    )
}

/// Streamed decode throughput: write the trace to a temp `.smt`, then
/// pull every record through a windowed mapped cursor — the engine's
/// streaming read path — counting millions of records decoded per
/// second. The summed fetch latencies double as an anti-DCE checksum
/// and a correctness pin against the in-memory records.
fn run_stream_decode(recs: &[TraceRecord], reps: usize) -> Row {
    let dir = std::env::temp_dir().join("simnet_bench_encode");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    let path = dir.join("stream.smt");
    let mut w = TraceWriter::create(&path).expect("trace writer");
    for r in recs {
        w.write(r).expect("trace write");
    }
    assert_eq!(w.finish().expect("trace finish") as usize, recs.len());

    let mut best = 0.0f64;
    let mut sum = 0u64;
    for _ in 0..reps {
        let (store, _) = open_store(&path, true, true, 0).expect("open store");
        let view = store.view();
        let mut cur = view.cursor();
        let t0 = Instant::now();
        let mut s = 0u64;
        for i in 0..cur.len() {
            s += u64::from(cur.get(i).f_lat);
        }
        let secs = t0.elapsed().as_secs_f64();
        best = best.max(recs.len() as f64 / secs.max(1e-12) / 1e6);
        sum = s;
    }
    let direct: u64 = recs.iter().map(|r| u64::from(r.f_lat)).sum();
    assert_eq!(sum, direct, "streamed decode must reproduce the records");
    let _ = std::fs::remove_file(&path);
    Row { name: "stream_decode".into(), seq: 0, mips: best }
}

/// Peak resident set size (VmHWM) in kB from `/proc/self/status`, or 0
/// where that file does not exist (non-Linux).
fn vm_hwm_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else { return 0 };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.split_whitespace().next().and_then(|v| v.parse().ok()).unwrap_or(0);
        }
    }
    0
}

fn write_json(path: &str, n: u64, quick: bool, rows: &[Row]) {
    let mut s = String::from("{\n");
    let _ = writeln!(s, "  \"bench\": \"encode\",");
    let _ = writeln!(s, "  \"n\": {n},");
    let _ = writeln!(s, "  \"quick\": {quick},");
    let _ = writeln!(s, "  \"slots\": {SLOTS},");
    let _ = writeln!(s, "  \"vm_hwm_kb\": {},", vm_hwm_kb());
    let _ = writeln!(s, "  \"configs\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            s,
            "    {{\"name\": \"{}\", \"seq\": {}, \"mips\": {:.4}}}{comma}",
            r.name, r.seq, r.mips
        );
    }
    let _ = writeln!(s, "  ]");
    s.push_str("}\n");
    std::fs::write(path, s).expect("write bench json");
    println!("\nwrote {path}");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick")
        || std::env::var("SIMNET_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();

    let n = common::bench_n(if quick { 60_000 } else { 300_000 });
    let cfg = SimConfig::default_o3();
    let b = find("xz").unwrap();
    let mut recs: Vec<TraceRecord> = Vec::new();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));

    let seqs: &[usize] = if quick { &[16] } else { &[8, 16, 32] };
    let reps = if quick { 2 } else { 3 };

    common::hr(&format!(
        "feature-encode throughput: legacy AoS vs SoA panels \
         ({n} instructions, {SLOTS} slots, best of {reps})"
    ));
    let mut table = Table::new(&["seq", "legacy M-enc/s", "SoA M-enc/s", "speedup"]);
    let mut rows = Vec::new();
    for &seq in seqs {
        let (legacy, soa) = run_seq(&recs, &cfg, seq, reps);
        table.row(vec![
            seq.to_string(),
            format!("{:.2}", legacy.mips),
            format!("{:.2}", soa.mips),
            format!("{:.2}x", soa.mips / legacy.mips.max(1e-12)),
        ]);
        rows.push(legacy);
        rows.push(soa);
    }
    print!("{}", table.render());

    let stream = run_stream_decode(&recs, reps);
    println!(
        "streamed decode: {:.2} M-rec/s (windowed mapped cursor); peak RSS {} kB",
        stream.mips,
        vm_hwm_kb()
    );
    rows.push(stream);

    if let Some(path) = json_path {
        write_json(&path, n, quick, &rows);
    }
}
