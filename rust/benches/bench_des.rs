//! Bench: reference-DES throughput (the paper's gem5 baseline line in
//! Figures 9/10) for both Table 2 configurations, across representative
//! benchmarks.

mod common;

use simnet::des::{simulate, SimConfig};
use simnet::stats::Table;
use simnet::workload::find;

fn main() {
    let n = common::bench_n(200_000);
    common::hr(&format!("DES throughput ({n} instructions/benchmark)"));
    let mut t = Table::new(&["config", "benchmark", "cpi", "MIPS"]);
    for cfg in [SimConfig::default_o3(), SimConfig::a64fx()] {
        for bench in ["perlbench", "mcf", "lbm", "exchange2"] {
            let b = find(bench).unwrap();
            let t0 = std::time::Instant::now();
            let stats = simulate(&cfg, b.workload(1).stream(), n, |_| {});
            let wall = t0.elapsed().as_secs_f64();
            t.row(vec![
                cfg.name.to_string(),
                bench.to_string(),
                format!("{:.3}", stats.cpi()),
                format!("{:.3}", n as f64 / wall / 1e6),
            ]);
        }
    }
    print!("{}", t.render());
}
