//! Bench: Figure 10 (overall throughput incl. training amortization) and
//! Figure 11 (feature attribution).

mod common;

use simnet::des::SimConfig;
use simnet::reports::{attribution, des_trace, figs, REFERENCE_SEED};
use simnet::workload::find;

fn main() {
    let n = common::bench_n(24_000);
    let cfg = SimConfig::default_o3();
    common::hr("Figure 10 (training amortization)");
    let models: Vec<String> = vec!["c3".into(), "rb".into()];
    let b = find("xz").unwrap();
    let t0 = std::time::Instant::now();
    let (recs, _) = des_trace(&cfg, &b, n, REFERENCE_SEED);
    let des_mips = n as f64 / t0.elapsed().as_secs_f64() / 1e6;
    // Unloadable models are skipped with the error on stderr
    // (fig10_sim_mips), never silently; simulation failures surface here.
    let report = match figs::fig10_sim_mips(&common::artifacts(), &models, &cfg, &recs, 64) {
        Ok(sim_mips) => figs::fig10(&common::artifacts(), &models, &cfg, &sim_mips, des_mips),
        Err(e) => Err(e),
    };
    match report {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("fig10 failed: {e}"),
    }
    common::hr("Figure 11 (feature attribution)");
    let spec = common::spec_or_fallback("c3");
    let benches: Vec<String> = vec!["gcc".into(), "mcf".into()];
    match attribution::attribution(&cfg, &spec, 192, Some(&benches)) {
        Ok(attr) => print!("{}", attribution::render(&attr)),
        Err(e) => eprintln!("attribution failed: {e}"),
    }
}
