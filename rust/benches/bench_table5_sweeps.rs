//! Bench: Table 5 (branch-predictor study) and the §5 L2-size and
//! ROB-size explorations.

mod common;

use simnet::des::SimConfig;
use simnet::reports::sweeps;

fn main() {
    let n = common::bench_n(32_000);
    let cfg = SimConfig::default_o3();
    let choice = common::spec_or_fallback("c3");
    let benches: Vec<String> = ["perlbench", "xalancbmk", "deepsjeng", "specrand_i"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    common::hr("Table 5 (branch predictors)");
    match sweeps::table5(&cfg, &choice, n, Some(&benches)) {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("table5 failed: {e}"),
    }
    common::hr("L2 size exploration (§5)");
    // L2 capacity only matters once a benchmark loops over a >256KB warm
    // set, so this sweep uses the L2-resident workloads and longer runs.
    let l2n = n * 6;
    let mem_benches: Vec<String> = vec!["omnetpp".into(), "xz".into(), "gcc".into()];
    match sweeps::l2_sweep(&cfg, &choice, l2n, &[256, 512, 1024, 2048, 4096], Some(&mem_benches)) {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("l2 sweep failed: {e}"),
    }
    common::hr("ROB size exploration (§5)");
    match sweeps::rob_sweep(&cfg, &choice, n, &[40, 80, 120], Some(&benches)) {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("rob sweep failed: {e}"),
    }
}
