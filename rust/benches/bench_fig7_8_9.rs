//! Bench: Figure 7 (parallel error vs sub-trace size), Figure 8
//! (throughput vs #sub-traces), Figure 9 (worker scaling + power).

mod common;

use simnet::des::SimConfig;
use simnet::reports::sweeps;

fn main() {
    let n = common::bench_n(24_000);
    let cfg = SimConfig::default_o3();
    let choice = common::spec_or_fallback("c3");
    let benches: Vec<String> = ["gcc", "mcf", "lbm"].iter().map(|s| s.to_string()).collect();
    common::hr("Figure 7 (parallel error vs sub-trace size)");
    match sweeps::fig7(&cfg, &choice, n, &[750, 1_500, 3_000, 6_000, 12_000], Some(&benches)) {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("fig7 failed: {e}"),
    }
    common::hr("Figure 8 (throughput vs #sub-traces)");
    match sweeps::fig8(&cfg, &choice, n, &[1, 4, 16, 64, 256, 1024], "xz") {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("fig8 failed: {e}"),
    }
    common::hr("Figure 9 (worker scaling + power efficiency)");
    match sweeps::fig9(&cfg, &choice, n, &[1, 2, 4, 8], 512, "xz") {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("fig9 failed: {e}"),
    }
}
