//! Bench: native inference microkernels — scalar zero-skip `dense_batch`
//! vs the packed blocked `dense_auto` path, in GFLOP/s, on the layer
//! shapes the shipped model families actually run (fc2/fc3 trunk
//! matmuls, the c3 conv-as-matmul, the 33-wide head).
//!
//! This is a *micro*bench: it times the kernels directly on synthetic
//! activations, outside the engine, so kernel-level regressions are
//! visible without trace-encode noise. The engine-level gate lives in
//! `bench_engine.rs` (`native_fc2_*` rows); this bench only publishes a
//! JSON artifact (`BENCH_kernels.json` in CI) for inspection and is not
//! compared against `bench/baseline.json`.
//!
//! Flags / env:
//! * `--quick` (or `SIMNET_BENCH_QUICK=1`) — fewer repetitions for the
//!   CI bench-smoke job.
//! * `--json PATH` — write per-shape results as JSON.

use std::fmt::Write as _;
use std::hint::black_box;
use std::time::Instant;

use simnet::predictor::native::kernels::{dense_auto, dense_batch, PackedMat};

/// xorshift64* — deterministic synthetic activations, no rand crate.
fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x.wrapping_mul(0x2545_f491_4f6c_dd1d)
}

/// Uniform value in [-1, 1), zeroed with probability `zero_pct`/100 —
/// `zero_pct` ~75 models post-ReLU activation sparsity.
fn rand_vec(len: usize, zero_pct: u64, state: &mut u64) -> Vec<f32> {
    (0..len)
        .map(|_| {
            let x = xorshift(state);
            if x % 100 < zero_pct {
                0.0
            } else {
                ((x >> 40) as f32) / (1u64 << 23) as f32 - 1.0
            }
        })
        .collect()
}

struct Shape {
    name: &'static str,
    d_in: usize,
    d_out: usize,
    rows: usize,
    zero_pct: u64,
}

struct ShapeResult {
    name: String,
    gflops_scalar: f64,
    gflops_blocked: f64,
}

/// Time `f` over `reps` calls and return GFLOP/s for a
/// `rows x d_in x d_out` matmul (2 FLOPs per MAC).
fn time_gflops(reps: usize, flops: f64, mut f: impl FnMut()) -> f64 {
    // One warmup call keeps first-touch page faults out of the timing.
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    flops * reps as f64 / t0.elapsed().as_secs_f64() / 1e9
}

fn bench_shape(s: &Shape, reps: usize) -> ShapeResult {
    let mut state = 0x5eed_0000_0000_0001u64 ^ ((s.d_in as u64) << 32) ^ s.d_out as u64;
    let x = rand_vec(s.rows * s.d_in, s.zero_pct, &mut state);
    let w = rand_vec(s.d_in * s.d_out, 0, &mut state);
    let bias = rand_vec(s.d_out, 0, &mut state);
    let pm = PackedMat::pack(&w, s.d_in, s.d_out);
    let mut y = vec![0.0f32; s.rows * s.d_out];
    let flops = 2.0 * (s.rows * s.d_in * s.d_out) as f64;

    let gflops_scalar = time_gflops(reps, flops, || {
        dense_batch(black_box(&x), black_box(&w), &bias, &mut y, s.rows, true);
        black_box(&y);
    });
    let gflops_blocked = time_gflops(reps, flops, || {
        dense_auto(black_box(&x), black_box(&w), &pm, &bias, &mut y, s.rows, true);
        black_box(&y);
    });
    let name = format!("{}_{}x{}_r{}_z{}", s.name, s.d_in, s.d_out, s.rows, s.zero_pct);
    ShapeResult { name, gflops_scalar, gflops_blocked }
}

fn write_json(path: &str, quick: bool, results: &[ShapeResult]) {
    let mut s = String::from("{\n");
    let _ = writeln!(s, "  \"bench\": \"kernels\",");
    let _ = writeln!(s, "  \"quick\": {quick},");
    let _ = writeln!(s, "  \"configs\": [");
    for (i, r) in results.iter().enumerate() {
        let comma = if i + 1 < results.len() { "," } else { "" };
        let _ = writeln!(
            s,
            "    {{\"name\": \"{}\", \"gflops_scalar\": {:.4}, \
             \"gflops_blocked\": {:.4}, \"speedup\": {:.4}}}{comma}",
            r.name,
            r.gflops_scalar,
            r.gflops_blocked,
            r.gflops_blocked / r.gflops_scalar.max(1e-12),
        );
    }
    let _ = writeln!(s, "  ]");
    s.push_str("}\n");
    std::fs::write(path, s).expect("write bench json");
    println!("\nwrote {path}");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick")
        || std::env::var("SIMNET_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let json_path =
        argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1)).cloned();

    // Layer shapes from the shipped families: fc2/fc3 trunk layers, the
    // c3 conv-as-matmul inner call, and the 33-wide output head. Dense
    // (z0) and ~75%-sparse (z75, post-ReLU-like) activations — the
    // sparse rows exercise the density dispatch in `dense_auto`.
    let shapes = [
        Shape { name: "fc2", d_in: 400, d_out: 256, rows: 64, zero_pct: 0 },
        Shape { name: "fc2", d_in: 400, d_out: 256, rows: 64, zero_pct: 75 },
        Shape { name: "fc3", d_in: 1600, d_out: 512, rows: 16, zero_pct: 0 },
        Shape { name: "c3conv", d_in: 100, d_out: 64, rows: 256, zero_pct: 75 },
        Shape { name: "head", d_in: 256, d_out: 33, rows: 64, zero_pct: 0 },
    ];
    let reps = if quick { 20 } else { 200 };

    println!("native kernel microbench ({reps} reps per shape)");
    println!("{:<24} {:>14} {:>15} {:>9}", "shape", "scalar GFLOP/s", "blocked GFLOP/s", "speedup");
    let mut results = Vec::new();
    for s in &shapes {
        let r = bench_shape(s, reps);
        println!(
            "{:<24} {:>14.3} {:>15.3} {:>8.2}x",
            r.name,
            r.gflops_scalar,
            r.gflops_blocked,
            r.gflops_blocked / r.gflops_scalar.max(1e-12),
        );
        results.push(r);
    }

    if let Some(path) = json_path {
        write_json(&path, quick, &results);
    }
}
