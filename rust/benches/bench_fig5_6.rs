//! Bench: Figure 5 (per-benchmark CPI, DES vs models) and Figure 6
//! (phase-level CPI curves).

mod common;

use simnet::des::SimConfig;
use simnet::reports::figs;

fn main() {
    let n = common::bench_n(20_000);
    let cfg = SimConfig::default_o3();
    let choices = vec![common::spec_or_fallback("c3"), common::spec_or_fallback("rb")];
    common::hr(&format!("Figure 5 ({n} instructions/benchmark)"));
    match figs::fig5(&cfg, &choices, n, 3_000, None) {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("fig5 failed: {e}"),
    }
    common::hr("Figure 6 (phase CPI, 4 representative benchmarks)");
    let benches: Vec<String> =
        ["bwaves", "xalancbmk", "cam4", "povray"].iter().map(|s| s.to_string()).collect();
    match figs::fig6(&cfg, &choices[..1], n, n / 40, Some(&benches)) {
        Ok(r) => print!("{r}"),
        Err(e) => eprintln!("fig6 failed: {e}"),
    }
}
