//! Bench: shared dynamic-batching engine throughput and predictor-batch
//! occupancy (paper §3.3, Figures 8/9).
//!
//! Two sweeps over the TablePredictor backend (artifact-free, so this
//! always runs):
//!
//! 1. Target-batch-size sweep at fixed concurrency — how the batch cap
//!    trades batches-per-round against occupancy.
//! 2. Shared engine vs per-worker pooling at EQUAL total sub-trace
//!    count — the seed's per-worker batches top out at
//!    `subtraces / workers` slots, while the shared engine keeps every
//!    batch full across job boundaries. Occupancy is the metric a real
//!    accelerator converts into throughput (Figure 9's device scaling).

mod common;

use std::time::Instant;

use simnet::coordinator::pool::PoolPredictor;
use simnet::coordinator::{
    simulate_pool_report, BatchEngine, EngineStats, JobSpec, PoolOptions, SimOutcome,
};
use simnet::des::{simulate, SimConfig};
use simnet::predictor::TablePredictor;
use simnet::stats::Table;
use simnet::trace::TraceRecord;
use simnet::workload::find;

fn run_shared(
    recs: &[TraceRecord],
    cfg: &SimConfig,
    workers: usize,
    subtraces: usize,
    target_batch: usize,
) -> (SimOutcome, EngineStats) {
    let opts = PoolOptions {
        workers,
        subtraces,
        predictor: PoolPredictor::Table { seq: 16 },
        window: 0,
        target_batch,
    };
    simulate_pool_report(recs, cfg, &opts).expect("shared engine run")
}

/// The seed's pooling model: one thread per worker, each with a PRIVATE
/// predictor batching only its own `subtraces / workers` sub-traces.
fn run_legacy(
    recs: &[TraceRecord],
    cfg: &SimConfig,
    workers: usize,
    subtraces: usize,
) -> (u64, f64, EngineStats) {
    let n = recs.len();
    let shard = n.div_ceil(workers).max(1);
    let base = subtraces / workers;
    let rem = subtraces % workers;
    let t0 = Instant::now();
    let results: Vec<(SimOutcome, EngineStats)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..workers {
            let lo = (w * shard).min(n);
            let hi = ((w + 1) * shard).min(n);
            let slice = &recs[lo..hi];
            let cfg = cfg.clone();
            let subs = (base + usize::from(w < rem)).max(1);
            handles.push(scope.spawn(move || {
                let mut p = TablePredictor::new(16);
                let mut engine = BatchEngine::new(&mut p, 0);
                engine.submit(JobSpec {
                    records: slice,
                    cfg: &cfg,
                    subtraces: subs,
                    window: 0,
                    cfg_feature: 0.0,
                });
                let report = engine.run().expect("legacy shard run");
                let stats = report.stats.clone();
                (report.merged(), stats)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut insts = 0u64;
    let mut agg = EngineStats::default();
    for (out, stats) in results {
        insts += out.instructions;
        agg.batches += stats.batches;
        agg.slots += stats.slots;
        agg.starved += stats.starved;
        agg.subtraces += stats.subtraces;
        agg.target_batch = agg.target_batch.max(stats.target_batch);
    }
    (insts, wall, agg)
}

fn mips(insts: u64, wall: f64) -> f64 {
    insts as f64 / wall.max(1e-12) / 1e6
}

fn main() {
    let n = common::bench_n(120_000);
    let cfg = SimConfig::default_o3();
    let b = find("xz").unwrap();
    let mut recs: Vec<TraceRecord> = Vec::new();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));

    common::hr(&format!("engine batch-size sweep ({n} instructions, 8 jobs, 256 sub-traces)"));
    let mut t = Table::new(&["target_batch", "MIPS", "mean_occupancy", "fill", "starved/batches"]);
    for target in [8usize, 32, 64, 128, 256] {
        let (out, stats) = run_shared(&recs, &cfg, 8, 256, target);
        t.row(vec![
            target.to_string(),
            format!("{:.3}", out.mips()),
            format!("{:.1}", stats.mean_occupancy()),
            format!("{:.2}", stats.fill_ratio()),
            format!("{}/{}", stats.starved, stats.batches),
        ]);
    }
    print!("{}", t.render());

    common::hr("shared engine vs per-worker pooling (equal total sub-trace count)");
    let mut t = Table::new(&["workers", "subtraces", "mode", "MIPS", "mean_occupancy"]);
    let mut all_higher = true;
    for workers in [2usize, 4, 8] {
        let total_subs = 256;
        let (legacy_insts, legacy_wall, legacy_stats) =
            run_legacy(&recs, &cfg, workers, total_subs);
        let (shared_out, shared_stats) = run_shared(&recs, &cfg, workers, total_subs, 0);
        all_higher &= shared_stats.mean_occupancy() > legacy_stats.mean_occupancy();
        t.row(vec![
            workers.to_string(),
            total_subs.to_string(),
            "per-worker".to_string(),
            format!("{:.3}", mips(legacy_insts, legacy_wall)),
            format!("{:.1}", legacy_stats.mean_occupancy()),
        ]);
        t.row(vec![
            workers.to_string(),
            total_subs.to_string(),
            "shared".to_string(),
            format!("{:.3}", shared_out.mips()),
            format!("{:.1}", shared_stats.mean_occupancy()),
        ]);
    }
    print!("{}", t.render());
    println!(
        "shared engine sustains higher mean batch occupancy at every point: {}",
        if all_higher { "YES" } else { "NO" }
    );
    println!(
        "(per-worker MIPS benefits from thread parallelism of the cheap table predictor; on a \
         real accelerator, batch occupancy is what converts to throughput)"
    );
}
