//! Bench: pipelined shared-batch engine throughput (paper §3.3, Figures
//! 8/9) — the encode-threads × target-batch sweep that anchors the repo's
//! performance trajectory.
//!
//! Runs the multi-job shared [`simnet::coordinator::BatchEngine`] over the
//! artifact-free TablePredictor backend and reports, per configuration:
//! MIPS, mean batch occupancy, fill ratio, and the predictor-idle
//! fraction (share of wall time the predictor spent waiting on feature
//! encoding — the quantity the pipeline exists to minimize).
//!
//! Flags / env:
//! * `--quick` (or `SIMNET_BENCH_QUICK=1`) — small trace + trimmed sweep
//!   for the CI bench-smoke job.
//! * `--json PATH` — additionally write the results as JSON
//!   (`BENCH_engine.json` in CI; compared against `bench/baseline.json`
//!   by `scripts/compare_bench.py`).
//! * `SIMNET_BENCH_N` — override the instruction count.

mod common;

use std::fmt::Write as _;

use simnet::coordinator::pool::PoolPredictor;
use simnet::coordinator::{simulate_pool_report, PoolOptions};
use simnet::des::{simulate, SimConfig};
use simnet::stats::Table;
use simnet::trace::TraceRecord;
use simnet::workload::find;

const JOBS: usize = 8;
const SUBTRACES: usize = 256;

struct Row {
    name: String,
    threads: usize,
    depth: usize,
    target: usize,
    mips: f64,
    occupancy: f64,
    fill: f64,
    idle: f64,
}

fn run_cfg(
    recs: &[TraceRecord],
    cfg: &SimConfig,
    target: usize,
    threads: usize,
    depth: usize,
) -> Row {
    let opts = PoolOptions {
        workers: JOBS,
        subtraces: SUBTRACES,
        predictor: PoolPredictor::Table { seq: 16 },
        window: 0,
        target_batch: target,
        encode_threads: threads,
        pipeline_depth: depth,
    };
    let (out, stats) = simulate_pool_report(recs, cfg, &opts).expect("engine run");
    let idle = stats.predictor_idle();
    Row {
        name: format!("t{threads}_d{depth}_b{target}"),
        threads,
        depth,
        target,
        mips: out.mips(),
        occupancy: stats.mean_occupancy(),
        fill: stats.fill_ratio(),
        idle,
    }
}

/// Best serial (threads<=1) and threaded (threads>1) MIPS across rows —
/// the pair the printed summary, the JSON, and the baseline gate consume.
fn best_mips(rows: &[Row]) -> (f64, f64) {
    let serial = rows.iter().filter(|r| r.threads <= 1).map(|r| r.mips).fold(0.0f64, f64::max);
    let threaded = rows.iter().filter(|r| r.threads > 1).map(|r| r.mips).fold(0.0f64, f64::max);
    (serial, threaded)
}

fn write_json(path: &str, n: u64, quick: bool, rows: &[Row]) {
    let (serial, threaded) = best_mips(rows);
    let mut s = String::from("{\n");
    let _ = writeln!(s, "  \"bench\": \"engine\",");
    let _ = writeln!(s, "  \"n\": {n},");
    let _ = writeln!(s, "  \"quick\": {quick},");
    let _ = writeln!(s, "  \"jobs\": {JOBS},");
    let _ = writeln!(s, "  \"subtraces\": {SUBTRACES},");
    let _ = writeln!(s, "  \"serial_mips\": {serial:.4},");
    let _ = writeln!(s, "  \"best_threaded_mips\": {threaded:.4},");
    let _ = writeln!(s, "  \"threaded_speedup\": {:.4},", threaded / serial.max(1e-12));
    let _ = writeln!(s, "  \"configs\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            s,
            "    {{\"name\": \"{}\", \"encode_threads\": {}, \"pipeline_depth\": {}, \
             \"target_batch\": {}, \"mips\": {:.4}, \"occupancy\": {:.2}, \"fill\": {:.3}, \
             \"predictor_idle\": {:.3}}}{comma}",
            r.name, r.threads, r.depth, r.target, r.mips, r.occupancy, r.fill, r.idle
        );
    }
    let _ = writeln!(s, "  ]");
    s.push_str("}\n");
    std::fs::write(path, s).expect("write bench json");
    println!("\nwrote {path}");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick")
        || std::env::var("SIMNET_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();

    let n = common::bench_n(if quick { 30_000 } else { 120_000 });
    let cfg = SimConfig::default_o3();
    let b = find("xz").unwrap();
    let mut recs: Vec<TraceRecord> = Vec::new();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));

    let threads_list: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let target_list: &[usize] = if quick { &[64] } else { &[32, 64, 128, 256] };

    common::hr(&format!(
        "pipelined engine sweep: encode-threads x target-batch \
         ({n} instructions, {JOBS} jobs, {SUBTRACES} sub-traces)"
    ));
    let mut table = Table::new(&[
        "encode_threads",
        "pipeline_depth",
        "target_batch",
        "MIPS",
        "mean_occupancy",
        "fill",
        "predictor_idle",
    ]);
    let mut rows = Vec::new();
    for &target in target_list {
        for &threads in threads_list {
            // Serial runs lockstep (depth 1); threaded runs double-buffer.
            let depth = if threads > 1 { 2 } else { 1 };
            let row = run_cfg(&recs, &cfg, target, threads, depth);
            table.row(vec![
                row.threads.to_string(),
                row.depth.to_string(),
                row.target.to_string(),
                format!("{:.3}", row.mips),
                format!("{:.1}", row.occupancy),
                format!("{:.2}", row.fill),
                format!("{:.2}", row.idle),
            ]);
            rows.push(row);
        }
    }
    print!("{}", table.render());

    let (serial, threaded) = best_mips(&rows);
    println!(
        "\nserial {serial:.3} MIPS vs best threaded {threaded:.3} MIPS \
         ({:.2}x) — pipelined beats serial: {}",
        threaded / serial.max(1e-12),
        if threaded > serial { "YES" } else { "NO" }
    );

    if let Some(path) = json_path {
        write_json(&path, n, quick, &rows);
    }
}
