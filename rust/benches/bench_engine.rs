//! Bench: pipelined shared-batch engine throughput (paper §3.3, Figures
//! 8/9) — the encode-threads × target-batch sweep that anchors the repo's
//! performance trajectory.
//!
//! Runs the multi-job shared engine (through `simnet::api::Simulation`
//! in pool mode) over the artifact-free TablePredictor backend and
//! reports, per configuration: MIPS, mean batch occupancy, fill ratio,
//! and the predictor-idle fraction (share of wall time the predictor
//! spent waiting on feature encoding — the quantity the pipeline exists
//! to minimize).
//!
//! Flags / env:
//! * `--quick` (or `SIMNET_BENCH_QUICK=1`) — small trace + trimmed sweep
//!   for the CI bench-smoke job.
//! * `--json PATH` — additionally write the results as JSON
//!   (`BENCH_engine.json` in CI; compared against `bench/baseline.json`
//!   by `scripts/compare_bench.py`). Each config entry embeds the run's
//!   full `SimReport` fields (`SimReport::json_fields`), so the bench
//!   JSON and `repro simulate-ml --json` share one report format.
//! * `SIMNET_BENCH_N` — override the instruction count.

mod common;

use std::fmt::Write as _;

use simnet::api::{PredictorSpec, SimReport, Simulation};
use simnet::coordinator::EngineOptions;
use simnet::des::{simulate, SimConfig};
use simnet::stats::Table;
use simnet::trace::TraceRecord;
use simnet::workload::find;

const JOBS: usize = 8;
const SUBTRACES: usize = 256;

struct Row {
    name: String,
    threads: usize,
    depth: usize,
    target: usize,
    report: SimReport,
}

impl Row {
    fn mips(&self) -> f64 {
        self.report.mips()
    }
}

fn run_cfg(
    recs: &[TraceRecord],
    cfg: &SimConfig,
    spec: PredictorSpec,
    prefix: &str,
    target: usize,
    threads: usize,
    depth: usize,
) -> Row {
    let report = Simulation::new()
        .records(recs)
        .config(cfg)
        .predictor(spec)
        .workers(JOBS)
        .subtraces(SUBTRACES)
        .engine(EngineOptions {
            target_batch: target,
            encode_threads: threads,
            pipeline_depth: depth,
            fork_predict: true,
        })
        .run()
        .expect("engine run");
    Row { name: format!("{prefix}t{threads}_d{depth}_b{target}"), threads, depth, target, report }
}

/// Best serial (threads<=1) and threaded (threads>1) MIPS across rows —
/// the pair the printed summary, the JSON, and the baseline gate consume.
fn best_mips(rows: &[Row]) -> (f64, f64) {
    let serial = rows.iter().filter(|r| r.threads <= 1).map(|r| r.mips()).fold(0.0f64, f64::max);
    let threaded = rows.iter().filter(|r| r.threads > 1).map(|r| r.mips()).fold(0.0f64, f64::max);
    (serial, threaded)
}

fn write_json(path: &str, n: u64, quick: bool, rows: &[Row]) {
    let (serial, threaded) = best_mips(rows);
    let mut s = String::from("{\n");
    let _ = writeln!(s, "  \"bench\": \"engine\",");
    let _ = writeln!(s, "  \"n\": {n},");
    let _ = writeln!(s, "  \"quick\": {quick},");
    let _ = writeln!(s, "  \"jobs\": {JOBS},");
    let _ = writeln!(s, "  \"subtraces\": {SUBTRACES},");
    let _ = writeln!(s, "  \"serial_mips\": {serial:.4},");
    let _ = writeln!(s, "  \"best_threaded_mips\": {threaded:.4},");
    let _ = writeln!(s, "  \"threaded_speedup\": {:.4},", threaded / serial.max(1e-12));
    let _ = writeln!(s, "  \"configs\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        // One object per config: the swept knobs plus the run's full
        // SimReport — same fields `repro simulate-ml --json` writes.
        let mut fields = vec![
            ("name", format!("\"{}\"", r.name)),
            ("encode_threads", r.threads.to_string()),
            ("pipeline_depth", r.depth.to_string()),
            ("target_batch", r.target.to_string()),
        ];
        fields.extend(r.report.json_fields().into_iter().filter(|(k, _)| *k != "windows"));
        let body: Vec<String> = fields.into_iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        let _ = writeln!(s, "    {{{}}}{comma}", body.join(", "));
    }
    let _ = writeln!(s, "  ]");
    s.push_str("}\n");
    std::fs::write(path, s).expect("write bench json");
    println!("\nwrote {path}");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick")
        || std::env::var("SIMNET_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();

    let n = common::bench_n(if quick { 30_000 } else { 120_000 });
    let cfg = SimConfig::default_o3();
    let b = find("xz").unwrap();
    let mut recs: Vec<TraceRecord> = Vec::new();
    simulate(&cfg, b.workload(1).stream(), n, |e| recs.push(TraceRecord::from(e)));

    let threads_list: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let target_list: &[usize] = if quick { &[64] } else { &[32, 64, 128, 256] };

    common::hr(&format!(
        "pipelined engine sweep: encode-threads x target-batch \
         ({n} instructions, {JOBS} jobs, {SUBTRACES} sub-traces)"
    ));
    let mut table = Table::new(&[
        "encode_threads",
        "pipeline_depth",
        "target_batch",
        "MIPS",
        "mean_occupancy",
        "fill",
        "predictor_idle",
    ]);
    let mut rows = Vec::new();
    for &target in target_list {
        for &threads in threads_list {
            // Serial runs lockstep (depth 1); threaded runs double-buffer.
            let depth = if threads > 1 { 2 } else { 1 };
            let row = run_cfg(&recs, &cfg, PredictorSpec::table(16), "", target, threads, depth);
            let stats = row.report.engine.clone().unwrap_or_default();
            table.row(vec![
                row.threads.to_string(),
                row.depth.to_string(),
                row.target.to_string(),
                format!("{:.3}", row.mips()),
                format!("{:.1}", stats.mean_occupancy()),
                format!("{:.2}", stats.fill_ratio()),
                format!("{:.2}", stats.predictor_idle()),
            ]);
            rows.push(row);
        }
    }
    print!("{}", table.render());

    // Native pure-Rust NN inference through the same engine. Artifact-free
    // (deterministic init weights at seq 8 unless trained fc2 artifacts
    // exist), so the CI bench-smoke gate can hold a floor on real matmul
    // throughput, not just the analytical table path.
    common::hr("native backend (pure-Rust fc2 inference)");
    // Two gated rows: the single-threaded run isolates the blocked-kernel
    // throughput itself ("simd" prefix), the threaded one adds the forked
    // per-worker handles on top. Both run in quick mode so the CI
    // bench-smoke gate holds floors on each.
    let native_cfgs: &[(&str, usize, usize)] =
        &[("native_fc2_simd_", 1, 1), ("native_fc2_", 4, 2)];
    for &(prefix, threads, depth) in native_cfgs {
        let spec = PredictorSpec::native(common::artifacts(), "fc2", 8);
        let row = run_cfg(&recs, &cfg, spec, prefix, 64, threads, depth);
        println!("  {}: {:.3} MIPS", row.name, row.mips());
        rows.push(row);
    }

    let (serial, threaded) = best_mips(&rows);
    println!(
        "\nserial {serial:.3} MIPS vs best threaded {threaded:.3} MIPS \
         ({:.2}x) — pipelined beats serial: {}",
        threaded / serial.max(1e-12),
        if threaded > serial { "YES" } else { "NO" }
    );

    if let Some(path) = json_path {
        write_json(&path, n, quick, &rows);
    }
}
