//! Bench: Table 4 — per-model prediction error, compute intensity, and
//! benchmark simulation error (train avg / sim avg / all avg) vs the DES.

mod common;

use simnet::des::SimConfig;
use simnet::reports::table4;

fn main() {
    let n = common::bench_n(20_000);
    common::hr(&format!("Table 4 ({n} instructions/benchmark)"));
    let models: Vec<String> = ["fc3", "c3", "c3_reg", "rb", "lstm2", "ithemal_lstm2"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cfg = SimConfig::default_o3();
    match table4::run(&common::artifacts(), &models, &cfg, n, 3_000) {
        Ok(report) => print!("{report}"),
        Err(e) => eprintln!("table4 failed: {e}"),
    }
}
