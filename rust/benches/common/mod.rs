//! Shared helpers for the bench harnesses (criterion is not vendored in
//! this image, so each bench is a plain `harness = false` binary that
//! prints its report table — one bench per paper table/figure).

use std::path::PathBuf;

use simnet::api::PredictorSpec;

/// Artifacts dir (env override: SIMNET_ARTIFACTS).
pub fn artifacts() -> PathBuf {
    std::env::var("SIMNET_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// ML predictor spec if the model's artifacts exist, else the analytical
/// fallback (so `cargo bench` always runs).
#[allow(dead_code)]
pub fn spec_or_fallback(model: &str) -> PredictorSpec {
    let dir = artifacts();
    if dir.join(format!("{model}.export")).exists() {
        // ml_tag resolves default weights (`<tag>.smw` when present).
        PredictorSpec::ml_tag(&dir, model, None)
    } else {
        eprintln!("[bench] artifacts for '{model}' missing — falling back to TablePredictor");
        PredictorSpec::table(32)
    }
}

/// Bench scale from env (SIMNET_BENCH_N), default n.
pub fn bench_n(default: u64) -> u64 {
    std::env::var("SIMNET_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn hr(title: &str) {
    println!("\n{}\n{}", title, "=".repeat(title.len()));
}
