//! End-to-end tests for the resident job server: a real daemon on a
//! real TCP socket, exercised through the same `protocol` helpers the
//! `repro` client subcommands use.
//!
//! The load-bearing property is report equivalence: a job that travels
//! through admission, the warm-predictor registry, and the scheduler
//! must produce the same `SimReport` JSON as a direct in-process
//! `Simulation::run()` — byte-identical once the timing-derived fields
//! (wall clock, MIPS, engine seconds) are scrubbed from both sides.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use simnet::api::job::{JobRequest, JobSource, Priority};
use simnet::api::{PredictorSpec, Simulation, WeightsSource};
use simnet::server::json::Value;
use simnet::server::{protocol, JobServer, ServerOptions};

fn quiet_opts() -> ServerOptions {
    ServerOptions { quiet: true, ..Default::default() }
}

/// Bind to an ephemeral port and run the daemon on a background thread.
fn start_server(opts: ServerOptions) -> (String, thread::JoinHandle<()>) {
    let server = JobServer::bind("127.0.0.1:0", opts).expect("bind job server");
    let addr = server.local_addr().to_string();
    let handle = thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

fn stop_server(addr: &str, handle: thread::JoinHandle<()>) {
    let v = protocol::roundtrip(addr, &protocol::shutdown_request()).expect("shutdown");
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
    handle.join().expect("server thread");
}

fn submit(addr: &str, job: &JobRequest) -> u64 {
    let v = protocol::roundtrip(addr, &protocol::submit_request(job, false)).expect("submit");
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "rejected: {}", v.render());
    v.get("id").and_then(Value::as_u64).expect("id")
}

/// Poll a job to completion and return its final status response.
fn wait_done(addr: &str, id: u64) -> Value {
    for _ in 0..1500 {
        let v = protocol::roundtrip(addr, &protocol::status_request(id)).expect("status");
        match v.get("state").and_then(Value::as_str) {
            Some("done") => return v,
            Some("failed") => panic!(
                "job {id} failed: {}",
                v.get("error").and_then(Value::as_str).unwrap_or("?")
            ),
            _ => thread::sleep(Duration::from_millis(20)),
        }
    }
    panic!("job {id} did not finish in time");
}

/// Canonical rendering with the timing-derived fields zeroed: two runs
/// of the same job agree on everything else.
fn scrubbed(report: &Value) -> String {
    let mut v = report.clone();
    for key in ["wall_seconds", "mips"] {
        if v.get(key).is_some() {
            v.set(key, Value::Num(0.0));
        }
    }
    if let Some(engine) = v.get_mut("engine") {
        if !engine.is_null() {
            for key in ["encode_seconds", "predict_seconds", "engine_seconds", "predictor_idle"] {
                if engine.get(key).is_some() {
                    engine.set(key, Value::Num(0.0));
                }
            }
        }
    }
    v.render()
}

/// Run the same job description in-process through the public
/// `Simulation` builder — the reference the daemon must match.
fn direct_report(job: &JobRequest) -> Value {
    let cfg = job.config.build().expect("config");
    let sim = Simulation::new()
        .config(&cfg)
        .predictor(job.predictor.clone())
        .subtraces(job.subtraces)
        .workers(job.workers)
        .window(job.window)
        .engine(job.engine)
        .input_seed(job.input_seed)
        .streaming(job.streaming)
        .source(job.source.to_trace_source(job.mmap));
    Value::parse(&sim.run().expect("direct run").to_json_compact()).expect("direct json")
}

fn native_fc2() -> PredictorSpec {
    PredictorSpec::native("artifacts", "fc2", 8).with_weights_source(WeightsSource::Init)
}

fn bench_job(spec: PredictorSpec, subtraces: usize) -> JobRequest {
    let mut job = JobRequest::new(JobSource::Bench { name: "gcc".into(), n: 3_000 }, spec);
    job.subtraces = subtraces;
    job.window = 500;
    job
}

#[test]
fn daemon_reports_match_direct_runs() {
    let (addr, handle) = start_server(quiet_opts());
    // 2x2: sequential and engine mode, table and native predictors.
    for (spec, subtraces) in [
        (PredictorSpec::table(16), 1usize),
        (PredictorSpec::table(16), 4),
        (native_fc2(), 1),
        (native_fc2(), 4),
    ] {
        let job = bench_job(spec, subtraces);
        let id = submit(&addr, &job);
        let status = wait_done(&addr, id);
        let daemon = status.get("report").expect("report in done status");
        let direct = direct_report(&job);
        assert_eq!(
            scrubbed(daemon),
            scrubbed(&direct),
            "daemon/direct mismatch for {} subtraces={subtraces}",
            job.predictor_key()
        );
    }
    stop_server(&addr, handle);
}

#[test]
fn concurrent_jobs_share_one_warm_predictor() {
    let (addr, handle) = start_server(ServerOptions { max_cobatch: 4, ..quiet_opts() });
    let gcc = bench_job(PredictorSpec::table(16), 4);
    let mut xz = bench_job(PredictorSpec::table(16), 4);
    xz.source = JobSource::Bench { name: "xz".into(), n: 2_000 };
    xz.priority = Priority::High;

    // Submit back-to-back so the scheduler may co-batch them; each job's
    // outcome must still match its solo in-process run (engine-stats
    // fields reflect the whole group, so compare outcome fields only).
    let ids = [submit(&addr, &gcc), submit(&addr, &xz)];
    for (id, job) in ids.iter().zip([&gcc, &xz]) {
        let status = wait_done(&addr, *id);
        let daemon = status.get("report").expect("report");
        let direct = direct_report(job);
        for key in ["instructions", "cycles", "cpi", "windows", "predictor", "config"] {
            assert_eq!(
                daemon.get(key),
                direct.get(key),
                "{key} mismatch for job {id} ({:?})",
                job.source
            );
        }
    }

    // Both tenants went through one registry entry.
    let stats = protocol::roundtrip(&addr, &protocol::stats_request()).expect("stats");
    let preds = stats.get("predictors").and_then(Value::as_arr).expect("predictors");
    assert_eq!(preds.len(), 1, "stats: {}", stats.render());
    assert_eq!(preds[0].get("key").and_then(Value::as_str), Some("table/seq=16"));
    assert_eq!(preds[0].get("jobs").and_then(Value::as_u64), Some(2));
    let jobs = stats.get("jobs").expect("jobs counts");
    assert_eq!(jobs.get("done").and_then(Value::as_u64), Some(2));
    stop_server(&addr, handle);
}

#[test]
fn streaming_submit_emits_events_and_final_report() {
    let (addr, handle) = start_server(quiet_opts());
    let job = bench_job(PredictorSpec::table(16), 1);

    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.write_all(protocol::submit_request(&job, true).as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let admit = Value::parse(line.trim_end()).expect("admission response");
    assert_eq!(admit.get("ok").and_then(Value::as_bool), Some(true));
    let id = admit.get("id").and_then(Value::as_u64).expect("id");

    let mut saw_lifecycle = false;
    loop {
        line.clear();
        assert_ne!(reader.read_line(&mut line).unwrap(), 0, "stream ended before done event");
        let ev = Value::parse(line.trim_end()).expect("event line");
        assert_eq!(ev.get("id").and_then(Value::as_u64), Some(id));
        match ev.get("event").and_then(Value::as_str) {
            Some("state") | Some("progress") => saw_lifecycle = true,
            Some("done") => {
                let report = ev.get("report").expect("report in done event");
                assert_eq!(scrubbed(report), scrubbed(&direct_report(&job)));
                break;
            }
            other => panic!("unexpected event {other:?}: {}", line.trim_end()),
        }
    }
    assert!(saw_lifecycle, "no state/progress events before done");
    stop_server(&addr, handle);
}

#[test]
fn mid_stream_disconnect_does_not_kill_the_job_or_daemon() {
    let (addr, handle) = start_server(quiet_opts());
    let job = bench_job(PredictorSpec::table(16), 4);

    let id = {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream.write_all(protocol::submit_request(&job, true).as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let admit = Value::parse(line.trim_end()).expect("admission response");
        admit.get("id").and_then(Value::as_u64).expect("id")
        // Connection dropped here, mid-event-stream.
    };

    // The job still runs to completion and the daemon still answers.
    let status = wait_done(&addr, id);
    assert!(status.get("report").is_some());
    let ping = protocol::roundtrip(&addr, &protocol::ping_request()).expect("ping");
    assert_eq!(ping.get("ok").and_then(Value::as_bool), Some(true));
    stop_server(&addr, handle);
}

#[test]
fn wire_protocol_rejects_garbage_without_dying() {
    let (addr, handle) = start_server(quiet_opts());
    let mut stream = TcpStream::connect(&addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut send = |line: &str| -> Value {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Value::parse(resp.trim_end()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
    };

    // Every case is a named error with a stable code, all down one
    // connection that stays usable throughout.
    for (line, code, needle) in [
        ("{nope", "bad_request", "json:"),
        ("[1, 2]", "bad_request", "expected a JSON object"),
        ("{\"cmd\": \"fly\"}", "bad_request", "unknown cmd"),
        ("{\"cmd\": \"submit\", \"job\": {\"sauce\": 1}}", "bad_job", "unknown field \"sauce\""),
        ("{\"cmd\": \"status\", \"id\": 99}", "not_found", "no job 99"),
    ] {
        let v = send(line);
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false), "line {line}");
        assert_eq!(v.get("code").and_then(Value::as_str), Some(code), "line {line}");
        let err = v.get("error").and_then(Value::as_str).unwrap_or("");
        assert!(err.contains(needle), "line {line}: error {err:?}");
    }

    // A job that parses but names a bogus benchmark is a bad_job.
    let bogus = JobRequest::new(
        JobSource::Bench { name: "not-a-bench".into(), n: 10 },
        PredictorSpec::table(8),
    );
    let v = send(&protocol::submit_request(&bogus, false));
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));
    assert_eq!(v.get("code").and_then(Value::as_str), Some("bad_job"));

    // Oversized request line: named rejection, connection survives.
    let huge = "x".repeat(protocol::MAX_LINE + 1024);
    let v = send(&huge);
    assert_eq!(v.get("code").and_then(Value::as_str), Some("line_too_long"));
    let v = send(&protocol::ping_request());
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
    stop_server(&addr, handle);
}

#[test]
fn full_queue_rejects_with_named_error() {
    // Capacity zero: every submit bounces with queue_full before any
    // predictor work happens.
    let (addr, handle) = start_server(ServerOptions { queue_capacity: 0, ..quiet_opts() });
    let job = bench_job(PredictorSpec::table(16), 1);
    let v = protocol::roundtrip(&addr, &protocol::submit_request(&job, false)).expect("submit");
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));
    assert_eq!(v.get("code").and_then(Value::as_str), Some("queue_full"));
    assert!(
        v.get("error").and_then(Value::as_str).unwrap_or("").contains("queue full"),
        "error: {}",
        v.render()
    );
    stop_server(&addr, handle);
}
