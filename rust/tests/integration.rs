//! Cross-module integration tests: workload → DES → trace → dataset →
//! context replay → ML simulation (and the PJRT runtime when artifacts
//! exist).
//!
//! Tests that need `artifacts/` (built by `make artifacts`) skip with a
//! message when it is absent, so `cargo test` passes on a fresh checkout.

use std::path::Path;

use simnet::coordinator::{simulate_parallel_with, simulate_sequential, ParallelOptions};
use simnet::des::{simulate, SimConfig};
use simnet::features::{ContextMode, ContextTracker};
use simnet::predictor::{LatencyPredictor, MlPredictor, TablePredictor};
use simnet::stats::cpi_error;
use simnet::trace::{build_dataset, read_trace, DatasetOptions, TraceRecord, TraceWriter};
use simnet::workload::{find, suite};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("c3.export").exists() {
        Some(p)
    } else {
        eprintln!("(artifacts/ not built — skipping PJRT-backed assertions)");
        None
    }
}

fn records(bench: &str, n: u64, seed: u64) -> (Vec<TraceRecord>, simnet::des::DesStats) {
    let cfg = SimConfig::default_o3();
    let b = find(bench).unwrap();
    let mut recs = Vec::new();
    let stats = simulate(&cfg, b.workload(seed).stream(), n, |e| recs.push(TraceRecord::from(e)));
    (recs, stats)
}

#[test]
fn full_pipeline_trace_to_dataset() {
    let dir = std::env::temp_dir().join("simnet_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = SimConfig::default_o3();

    // Trace file round trip through the real writer.
    let (recs, stats) = records("gcc", 10_000, 0);
    let trace_path = dir.join("gcc.smt");
    let mut w = TraceWriter::create(&trace_path).unwrap();
    for r in &recs {
        w.write(r).unwrap();
    }
    assert_eq!(w.finish().unwrap(), 10_000);
    let back = read_trace(&trace_path).unwrap();
    assert_eq!(back.len(), recs.len());
    assert!(stats.cpi() > 0.3);

    // Dataset build over the same records in both context modes.
    for (mode, name) in
        [(ContextMode::SimNet, "ds_simnet.smd"), (ContextMode::Ithemal, "ds_ithemal.smd")]
    {
        let opts = DatasetOptions { seq_len: 32, dedup: true, limit: 0, mode, cfg_feature: 0.0 };
        let (written, dups) = build_dataset(back.iter(), &cfg, &opts, &dir.join(name)).unwrap();
        assert!(written > 1_000, "{name}: too few samples ({written})");
        assert_eq!(written + dups, 10_000);
    }
}

#[test]
fn eq1_invariant_holds_for_every_benchmark() {
    // Paper Eq. 1 on the DES side: cycles == sum(F) + Delta with small
    // Delta — for ALL 25 benchmarks (not just the ones unit tests use).
    let cfg = SimConfig::default_o3();
    for b in suite() {
        let mut sum_f = 0u64;
        let stats = simulate(&cfg, b.workload(0).stream(), 8_000, |e| sum_f += e.f_lat as u64);
        assert!(stats.cycles >= sum_f, "{}: cycles < sum F", b.name);
        let delta = stats.cycles - sum_f;
        assert!(
            (delta as f64) < 0.20 * stats.cycles as f64,
            "{}: drain {} too large vs {}",
            b.name,
            delta,
            stats.cycles
        );
    }
}

#[test]
fn context_replay_oracle_is_close_for_all_benchmarks() {
    // Replaying ground-truth latencies through the ML-side context tracker
    // must land near the DES total: this bounds the methodology error of
    // the instruction-centric simulator for every workload class.
    let cfg = SimConfig::default_o3();
    for b in suite() {
        let (recs, stats) = {
            let mut recs = Vec::new();
            let stats =
                simulate(&cfg, b.workload(0).stream(), 10_000, |e| recs.push(TraceRecord::from(e)));
            (recs, stats)
        };
        let mut tracker = ContextTracker::new(&cfg);
        for r in &recs {
            tracker.push(&r.inst, &r.hist, r.f_lat, r.e_lat, r.s_lat);
        }
        let cycles = tracker.cur_tick + tracker.drain();
        let ratio = cycles as f64 / stats.cycles as f64;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "{}: oracle replay ratio {ratio:.3}",
            b.name
        );
    }
}

#[test]
fn parallel_error_shrinks_with_subtrace_size() {
    // Figure 7's qualitative claim: bigger sub-traces -> closer to the
    // sequential result (averaged over benchmarks to smooth noise).
    let cfg = SimConfig::default_o3();
    let mut p = TablePredictor::new(16);
    let mut err_small_sum = 0.0;
    let mut err_big_sum = 0.0;
    for bench in ["gcc", "mcf", "xalancbmk", "lbm"] {
        let (recs, _) = records(bench, 24_000, 1);
        let seq = simulate_sequential(&recs, &cfg, &mut p, 0).unwrap();
        let subs = |subtraces| ParallelOptions { subtraces, ..ParallelOptions::default() };
        let small_opts = subs(24_000 / 150);
        let big_opts = subs(24_000 / 6_000);
        let small = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p, &small_opts).unwrap();
        let big = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p, &big_opts).unwrap();
        err_small_sum += cpi_error(small.cpi(), seq.cpi());
        err_big_sum += cpi_error(big.cpi(), seq.cpi());
    }
    assert!(
        err_big_sum <= err_small_sum + 1e-9,
        "avg err with 6000-inst subtraces ({err_big_sum:.4}) should not exceed 150-inst ({err_small_sum:.4})"
    );
}

#[test]
fn ml_runtime_smoke_and_accuracy() {
    let Some(dir) = artifacts() else { return };
    let (recs, stats) = records("leela", 4_000, 1);
    let cfg = SimConfig::default_o3();
    let mut p = MlPredictor::load(dir, "c3", None).expect("load c3");
    assert_eq!(p.seq_len(), 32);
    let opts = ParallelOptions { subtraces: 16, ..ParallelOptions::default() };
    let out = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p, &opts).unwrap();
    assert_eq!(out.instructions, 4_000);
    let err = cpi_error(out.cpi(), stats.cpi());
    // Trained artifact should beat a coin flip by a wide margin; exact
    // accuracy is reported by the benches, this is a regression floor.
    assert!(err < 0.60, "trained c3 err {err:.3} vs des");
    assert_eq!(p.served(), 4_000);
}

#[test]
fn ml_runtime_batch_consistency() {
    // The same encoded input must decode to the same latencies whether it
    // goes through the b=1 or the b=64 executable (padding correctness).
    let Some(dir) = artifacts() else { return };
    let mut p = MlPredictor::load(dir, "c3", None).expect("load c3");
    let width = p.seq_len() * simnet::features::NUM_FEATURES;
    let (recs, _) = records("namd", 300, 1);
    let cfg = SimConfig::default_o3();
    let mut tracker = ContextTracker::new(&cfg);
    let mut one = vec![0.0f32; width];
    let mut inputs = Vec::new();
    for r in &recs[..65] {
        tracker.encode_input(&r.inst, &r.hist, p.seq_len(), &mut one);
        inputs.extend_from_slice(&one);
        tracker.push(&r.inst, &r.hist, r.f_lat, r.e_lat, r.s_lat);
    }
    let batched = p.predict(&inputs, 65).unwrap();
    let mut singles = Vec::new();
    for i in 0..65 {
        singles.push(p.predict(&inputs[i * width..(i + 1) * width], 1).unwrap()[0]);
    }
    assert_eq!(batched, singles);
}

#[test]
fn ithemal_context_mode_selected_by_model_name() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("ithemal_lstm2.export").exists() {
        eprintln!("(ithemal_lstm2 artifacts missing — skipping)");
        return;
    }
    let p = MlPredictor::load(dir, "ithemal_lstm2", None).expect("load ithemal");
    assert_eq!(p.context_mode(), ContextMode::Ithemal);
    let p2 = MlPredictor::load(dir, "c3", None).expect("load c3");
    assert_eq!(p2.context_mode(), ContextMode::SimNet);
}

#[test]
fn a64fx_pipeline_end_to_end_with_table_predictor() {
    let cfg = SimConfig::a64fx();
    let b = find("bwaves").unwrap();
    let mut recs = Vec::new();
    let stats = simulate(&cfg, b.workload(1).stream(), 8_000, |e| recs.push(TraceRecord::from(e)));
    let mut p = TablePredictor::new(32);
    let out = simulate_sequential(&recs, &cfg, &mut p, 0).unwrap();
    assert_eq!(out.instructions, 8_000);
    assert!(out.cpi() > 0.1 && stats.cpi() > 0.1);
}

#[test]
fn config_sweeps_change_des_behavior() {
    // L2 size must matter for a memory-bound workload; ROB size must
    // matter for an ILP-bound workload. Guards the sweep reports against
    // silently-constant configs.
    // A 64KB L2 forces capacity misses that the default 1MB absorbs.
    let mut small_l2 = SimConfig::default_o3();
    small_l2.l2.size = 64 << 10;
    let b = find("mcf").unwrap();
    let small = simulate(&small_l2, b.workload(1).stream(), 50_000, |_| {});
    let base = simulate(&SimConfig::default_o3(), b.workload(1).stream(), 50_000, |_| {});
    assert!(
        small.cycles > base.cycles,
        "64KB L2 not slower than 1MB on mcf: {} vs {}",
        small.cycles,
        base.cycles
    );
}
