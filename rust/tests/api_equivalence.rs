//! Equivalence tests pinning `api::Simulation` to the legacy entry points
//! it unifies: builder-sequential must reproduce `simulate_sequential`,
//! builder-engine the direct `BatchEngine` path, and builder-pool the
//! direct `simulate_pool_report` call — byte-identical cycle counts,
//! windows, and batching statistics, not "close enough". Plus the
//! `SimReport::to_json` golden test for the machine-readable format.

use simnet::api::{ExecMode, PredictorSpec, SimReport, Simulation};
use simnet::coordinator::{
    simulate_parallel_with, simulate_pool_report, simulate_sequential, BatchEngine, EngineOptions,
    EngineStats, JobSpec, ParallelOptions, PoolOptions, SimOutcome,
};
use simnet::des::{simulate, SimConfig};
use simnet::predictor::TablePredictor;
use simnet::trace::{InputStats, TraceRecord};
use simnet::workload::find;

fn records(bench: &str, n: u64) -> (Vec<TraceRecord>, SimConfig) {
    let cfg = SimConfig::default_o3();
    let b = find(bench).unwrap();
    let mut recs = Vec::new();
    simulate(&cfg, b.workload(0).stream(), n, |e| recs.push(TraceRecord::from(e)));
    (recs, cfg)
}

#[test]
fn builder_sequential_matches_legacy_sequential() {
    let (recs, cfg) = records("gcc", 6_000);
    let mut p = TablePredictor::new(16);
    let legacy = simulate_sequential(&recs, &cfg, &mut p, 1_000).unwrap();

    let report = Simulation::new()
        .records(&recs)
        .config(&cfg)
        .predictor(PredictorSpec::table(16))
        .window(1_000)
        .run()
        .unwrap();
    assert_eq!(report.mode, ExecMode::Sequential);
    assert!(report.engine.is_none());
    assert_eq!(report.outcome.instructions, legacy.instructions);
    assert_eq!(report.outcome.cycles, legacy.cycles);
    assert_eq!(report.outcome.windows, legacy.windows);
    assert_eq!(report.outcome.inferences, legacy.inferences);
}

#[test]
fn builder_engine_matches_legacy_batch_engine() {
    let (recs, cfg) = records("leela", 4_000);
    let opts =
        EngineOptions { target_batch: 8, encode_threads: 1, pipeline_depth: 1, fork_predict: true };
    let mut p = TablePredictor::new(16);
    let mut engine = BatchEngine::with_options(&mut p, opts);
    let job = JobSpec {
        records: (&recs[..]).into(),
        cfg: &cfg,
        subtraces: 4,
        window: 500,
        cfg_feature: 0.0,
        progress: None,
    };
    engine.submit(job);
    let legacy = engine.run().unwrap();
    let legacy_stats = legacy.stats.clone();
    let legacy_out = legacy.merged();

    let report = Simulation::new()
        .records(&recs)
        .config(&cfg)
        .predictor(PredictorSpec::table(16))
        .subtraces(4)
        .window(500)
        .engine(opts)
        .run()
        .unwrap();
    assert_eq!(report.mode, ExecMode::Engine);
    assert_eq!(report.outcome.instructions, legacy_out.instructions);
    assert_eq!(report.outcome.cycles, legacy_out.cycles);
    assert_eq!(report.outcome.windows, legacy_out.windows);
    let stats = report.engine.expect("engine stats");
    assert_eq!(stats.batches, legacy_stats.batches);
    assert_eq!(stats.slots, legacy_stats.slots);
    assert_eq!(stats.starved, legacy_stats.starved);
    assert_eq!(stats.target_batch, legacy_stats.target_batch);
    assert_eq!(stats.subtraces, legacy_stats.subtraces);
}

#[test]
fn builder_engine_matches_legacy_parallel() {
    // The one-shot parallel entry point (unbounded batch, serial
    // encode) must also be reproduced exactly.
    let (recs, cfg) = records("leela", 4_000);
    let mut p = TablePredictor::new(16);
    let opts = ParallelOptions { subtraces: 4, ..ParallelOptions::default() };
    let legacy = simulate_parallel_with((&recs[..]).into(), &cfg, &mut p, &opts).unwrap();

    let report = Simulation::new()
        .records(&recs)
        .config(&cfg)
        .predictor(PredictorSpec::table(16))
        .subtraces(4)
        .engine(EngineOptions {
            target_batch: 0,
            encode_threads: 1,
            pipeline_depth: 1,
            fork_predict: true,
        })
        .run()
        .unwrap();
    assert_eq!(report.outcome.instructions, legacy.instructions);
    assert_eq!(report.outcome.cycles, legacy.cycles);
    assert_eq!(report.outcome.windows, legacy.windows);
}

#[test]
fn builder_pool_matches_legacy_pool() {
    let (recs, cfg) = records("gcc", 6_000);
    let engine =
        EngineOptions { target_batch: 0, encode_threads: 1, pipeline_depth: 1, fork_predict: true };
    let opts = PoolOptions {
        workers: 3,
        subtraces: 12,
        window: 500,
        cfg_feature: 0.0,
        engine,
        progress: None,
    };
    let mut p = TablePredictor::new(16);
    let (legacy_out, legacy_stats) = simulate_pool_report(&recs, &cfg, &mut p, &opts).unwrap();

    let report = Simulation::new()
        .records(&recs)
        .config(&cfg)
        .predictor(PredictorSpec::table(16))
        .workers(3)
        .subtraces(12)
        .window(500)
        .engine(engine)
        .run()
        .unwrap();
    assert_eq!(report.mode, ExecMode::Pool);
    assert_eq!(report.outcome.instructions, legacy_out.instructions);
    assert_eq!(report.outcome.cycles, legacy_out.cycles);
    assert_eq!(report.outcome.windows, legacy_out.windows);
    let stats = report.engine.expect("pool stats");
    assert_eq!(stats.batches, legacy_stats.batches);
    assert_eq!(stats.slots, legacy_stats.slots);
    assert_eq!(stats.subtraces, legacy_stats.subtraces);
}

#[test]
fn sim_report_to_json_golden() {
    let report = SimReport {
        predictor: "table".into(),
        mode: ExecMode::Engine,
        bench: Some("gcc".into()),
        config: "default_o3".into(),
        outcome: SimOutcome {
            instructions: 1000,
            cycles: 1500,
            windows: vec![(500, 700), (500, 800)],
            wall_seconds: 0.25,
            inferences: 1000,
        },
        engine: Some(EngineStats {
            batches: 250,
            slots: 1000,
            target_batch: 4,
            starved: 2,
            filled: 248,
            subtraces: 4,
            encode_threads: 1,
            pipeline_depth: 1,
            encode_seconds: 0.0625,
            predict_seconds: 0.125,
            engine_seconds: 0.25,
        }),
        des_cpi: Some(1.25),
        input: InputStats {
            bytes_mapped: 640,
            bytes_copied: 0,
            peak_resident_records: 10,
            window_records: 0,
        },
    };
    let expected = concat!(
        "{\n",
        "  \"schema\": \"simnet.sim_report/v1\",\n",
        "  \"predictor\": \"table\",\n",
        "  \"mode\": \"engine\",\n",
        "  \"bench\": \"gcc\",\n",
        "  \"config\": \"default_o3\",\n",
        "  \"instructions\": 1000,\n",
        "  \"cycles\": 1500,\n",
        "  \"inferences\": 1000,\n",
        "  \"cpi\": 1.500000,\n",
        "  \"des_cpi\": 1.250000,\n",
        "  \"cpi_err_pct\": 20.000000,\n",
        "  \"mips\": 0.004000,\n",
        "  \"wall_seconds\": 0.250000,\n",
        "  \"bytes_mapped\": 640,\n",
        "  \"bytes_copied\": 0,\n",
        "  \"peak_resident_records\": 10,\n",
        "  \"window_records\": 0,\n",
        "  \"windows\": [[500, 700], [500, 800]],\n",
        "  \"engine\": {\"batches\": 250, \"slots\": 1000, \"target_batch\": 4, ",
        "\"starved\": 2, \"filled\": 248, \"subtraces\": 4, \"encode_threads\": 1, ",
        "\"pipeline_depth\": 1, \"mean_occupancy\": 4.000000, \"fill\": 1.000000, ",
        "\"predictor_idle\": 0.500000, \"encode_seconds\": 0.062500, ",
        "\"predict_seconds\": 0.125000, \"engine_seconds\": 0.250000}\n",
        "}\n",
    );
    assert_eq!(report.to_json(), expected);
}

#[test]
fn real_run_json_has_required_keys() {
    // The acceptance shape of `repro simulate-ml --json`: instructions,
    // cpi, mips, and engine stats must be present.
    let report = Simulation::new()
        .bench("gcc", 2_000)
        .predictor(PredictorSpec::table(16))
        .subtraces(4)
        .run()
        .unwrap();
    let json = report.to_json();
    for key in ["\"instructions\":", "\"cpi\":", "\"mips\":", "\"engine\": {", "\"des_cpi\":"] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    assert!(json.contains("\"bench\": \"gcc\""));
}
