//! Property-based tests over randomized inputs.
//!
//! The proptest crate is not vendored in this image, so these use the
//! repo's own deterministic RNG to sweep hundreds of random cases per
//! property — same idea, explicit seeds, fully reproducible failures
//! (every assertion message carries the case seed).

use simnet::des::{DesCpu, SimConfig};
use simnet::features::{ContextMode, ContextTracker, NUM_FEATURES};
use simnet::history::tagarray::TagArray;
use simnet::history::HistoryInfo;
use simnet::isa::{Inst, OpClass, REG_NONE};
use simnet::runtime::{decode_row, OutputMode, HEAD_OUT};
use simnet::trace::{TraceRecord, RECORD_SIZE};
use simnet::workload::rng::Rng;
use simnet::workload::{build_program, Executor, Personality};

/// Random instruction generator for property sweeps.
fn random_inst(rng: &mut Rng) -> Inst {
    let op = OpClass::ALL[rng.index(OpClass::ALL.len())];
    let mut inst = Inst {
        pc: rng.below(1 << 30) & !3,
        op,
        mem_addr: if op.is_mem() { rng.below(1 << 34).max(8) & !7 } else { 0 },
        mem_size: if op.is_mem() { [1, 2, 4, 8, 16][rng.index(5)] } else { 0 },
        target: if op.is_control() { rng.below(1 << 30) & !3 } else { 0 },
        taken: op.is_control() && rng.chance(0.7),
        ..Default::default()
    };
    for s in inst.srcs.iter_mut() {
        *s = if rng.chance(0.4) { rng.index(64) as i8 } else { REG_NONE };
    }
    for d in inst.dsts.iter_mut() {
        *d = if rng.chance(0.25) { rng.index(64) as i8 } else { REG_NONE };
    }
    inst
}

fn random_hist(rng: &mut Rng, inst: &Inst) -> HistoryInfo {
    HistoryInfo {
        mispredict: inst.op.is_control() && rng.chance(0.1),
        fetch_level: 1 + rng.index(3) as u8,
        fetch_walk: [rng.chance(0.05), rng.chance(0.05), rng.chance(0.05)],
        fetch_wb: [false, rng.chance(0.02)],
        data_level: if inst.op.is_mem() { 1 + rng.index(3) as u8 } else { 0 },
        data_walk: [rng.chance(0.05), rng.chance(0.05), rng.chance(0.05)],
        data_wb: [rng.chance(0.05), rng.chance(0.02), rng.chance(0.02)],
    }
}

#[test]
fn prop_trace_record_roundtrip() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..500 {
        let inst = random_inst(&mut rng);
        let rec = TraceRecord {
            hist: random_hist(&mut rng, &inst),
            inst,
            f_lat: rng.below(10_000) as u32,
            e_lat: rng.below(10_000) as u32,
            s_lat: rng.below(10_000) as u32,
        };
        let mut buf = [0u8; RECORD_SIZE];
        rec.encode(&mut buf);
        assert_eq!(TraceRecord::decode(&buf), rec, "case {case}");
    }
}

#[test]
fn prop_tagarray_matches_reference_lru() {
    // Reference model: per-set Vec with MRU-front ordering.
    let mut rng = Rng::new(0xCACE);
    for case in 0..40 {
        let sets = 1 << rng.index(5);
        let ways = 1 + rng.index(7);
        let mut tags = TagArray::new(sets, ways, 64);
        let mut reference: Vec<Vec<u64>> = vec![Vec::new(); sets];
        for _ in 0..2_000 {
            let addr = rng.below(1 << 16) * 64;
            let block = addr >> 6;
            let set = (block as usize) % sets;
            let expect_hit = reference[set].contains(&block);
            let got = tags.access(addr, false);
            assert_eq!(got.hit, expect_hit, "case {case} sets={sets} ways={ways}");
            // Update reference LRU.
            reference[set].retain(|&b| b != block);
            reference[set].insert(0, block);
            reference[set].truncate(ways);
        }
    }
}

#[test]
fn prop_context_tracker_invariants() {
    let cfg = SimConfig::default_o3();
    let cap = cfg.max_context() + cfg.sq_entries;
    for seed in 0..30 {
        let mut rng = Rng::new(seed);
        let mut tracker = ContextTracker::new(&cfg);
        let mut last_tick = 0;
        for _ in 0..400 {
            let inst = random_inst(&mut rng);
            let hist = random_hist(&mut rng, &inst);
            let f = rng.below(20) as u32;
            let e = 1 + rng.below(300) as u32;
            let s = if inst.is_store() { e + 1 + rng.below(200) as u32 } else { 0 };
            tracker.push(&inst, &hist, f, e, s);
            assert!(tracker.len() <= cap, "seed {seed}: len {} > cap {cap}", tracker.len());
            assert!(tracker.cur_tick >= last_tick, "seed {seed}: clock went backwards");
            last_tick = tracker.cur_tick;
        }
        tracker.drain();
        assert!(tracker.is_empty(), "seed {seed}: drain left instructions");
    }
}

#[test]
fn prop_ithemal_window_is_exact_recency() {
    let cfg = SimConfig::default_o3();
    for seed in 100..110 {
        let mut rng = Rng::new(seed);
        let mut tracker = ContextTracker::with_mode(&cfg, ContextMode::Ithemal);
        let mut pcs = Vec::new();
        for _ in 0..300 {
            let inst = random_inst(&mut rng);
            pcs.push(inst.pc);
            tracker.push(&inst, &HistoryInfo::default(), 1, 5, 0);
        }
        // Encode with a window of 8: slots 1..8 must be the last 7 pushed
        // instructions in reverse order (checked via the op-independent
        // residence feature being 0 and the fetch-line dep flag path is
        // exercised elsewhere; here check count only).
        let probe = random_inst(&mut rng);
        let mut buf = vec![0.0f32; 8 * NUM_FEATURES];
        tracker.encode_input(&probe, &HistoryInfo::default(), 8, &mut buf);
        // All 7 context slots are populated (fixed window never shrinks).
        for slot in 1..8 {
            let s = &buf[slot * NUM_FEATURES..(slot + 1) * NUM_FEATURES];
            assert!(
                s.iter().any(|&x| x != 0.0),
                "seed {seed}: ithemal context slot {slot} empty"
            );
            // Latency features are always zero in Ithemal mode.
            assert_eq!(s[41], 0.0, "residence leaked into ithemal features");
            assert_eq!(s[42], 0.0, "exec lat leaked into ithemal features");
        }
    }
}

#[test]
fn prop_des_latency_invariants_random_workloads() {
    for seed in 0..12 {
        let mut rng = Rng::new(seed * 31 + 7);
        // Random personality within sane bounds.
        let p = Personality {
            load_frac: 0.05 + rng.f64() * 0.35,
            store_frac: 0.02 + rng.f64() * 0.15,
            fp_frac: rng.f64() * 0.6,
            chase_frac: rng.f64() * 0.6,
            bernoulli_p: rng.f64() * 0.5,
            block_len: 2.0 + rng.f64() * 10.0,
            ..Default::default()
        };
        let prog = build_program(&p, seed);
        let cfg = SimConfig::default_o3();
        let mut cpu = DesCpu::new(&cfg);
        let mut last_fetch = 0u64;
        for inst in Executor::new(&prog, seed).take(5_000) {
            let e = cpu.step(&inst);
            assert!(e.fetch_cycle >= last_fetch, "seed {seed}: fetch not monotone");
            assert_eq!(e.fetch_cycle - last_fetch, e.f_lat as u64, "seed {seed}: F mismatch");
            assert!(e.e_lat >= 1, "seed {seed}: E < 1");
            if inst.is_store() {
                assert!(e.s_lat > e.e_lat, "seed {seed}: store S <= E");
            } else {
                assert_eq!(e.s_lat, 0, "seed {seed}: non-store with S");
            }
            last_fetch = e.fetch_cycle;
        }
        let stats = cpu.finish();
        let cpi = stats.cpi();
        assert!((0.2..100.0).contains(&cpi), "seed {seed}: cpi {cpi}");
    }
}

#[test]
fn prop_decode_row_bounds() {
    let mut rng = Rng::new(0xDEC0DE);
    for case in 0..500 {
        let row: Vec<f32> =
            (0..HEAD_OUT).map(|_| (rng.f64() as f32 - 0.5) * 20.0).collect();
        for mode in [OutputMode::Hybrid, OutputMode::Regression] {
            let (f, e, s) = decode_row(&row, mode);
            // Latencies are bounded by the regression ceiling.
            let ceil = (10.0 * 20.0 * 256.0) as u32;
            assert!(f < ceil && e < ceil && s < ceil, "case {case}: runaway decode");
            if mode == OutputMode::Hybrid {
                // Hybrid never returns 1..=8 from the regression path, and
                // class path returns < 9; so any value in 0..=8 is a class.
                // (Consistency: re-decoding is deterministic.)
                assert_eq!((f, e, s), decode_row(&row, mode));
            }
        }
    }
}

#[test]
fn prop_workload_streams_are_infinite_and_valid() {
    for seed in 0..10 {
        let p = Personality::default();
        let prog = build_program(&p, seed + 1000);
        let mut count = 0u64;
        for inst in Executor::new(&prog, seed).take(20_000) {
            count += 1;
            if inst.op.is_mem() {
                assert!(inst.mem_addr > 0, "seed {seed}: mem op without address");
            }
            assert_eq!(inst.pc % 4, 0, "seed {seed}: misaligned pc");
        }
        assert_eq!(count, 20_000, "seed {seed}: stream ended early");
    }
}
