//! Streaming windowed simulation: the bounded-memory trace pull
//! (`RecordStore` mapped cursors) must be byte-identical to the full
//! up-front decode through every execution mode and predictor backend,
//! window edge shapes must stream correctly, and the resident-record
//! peak must stay within `subtraces x window`.

use std::path::{Path, PathBuf};

use simnet::api::{PredictorSpec, SimReport, Simulation, WeightsSource};
use simnet::des::{simulate, SimConfig};
use simnet::trace::mmap::MmapTrace;
use simnet::trace::{TraceRecord, TraceWriter, DEFAULT_STREAM_WINDOW};
use simnet::workload::find;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("simnet_streaming");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// Write an `n`-instruction DES trace for `bench` and return its path.
fn write_trace(name: &str, bench: &str, n: u64) -> PathBuf {
    let path = tmp(name);
    let cfg = SimConfig::default_o3();
    let b = find(bench).unwrap();
    let mut w = TraceWriter::create(&path).unwrap();
    simulate(&cfg, b.workload(0).stream(), n, |e| {
        w.write(&TraceRecord::from(e)).unwrap();
    });
    assert_eq!(w.finish().unwrap(), n);
    path
}

fn native_fc2() -> PredictorSpec {
    PredictorSpec::native("artifacts", "fc2", 8).with_weights_source(WeightsSource::Init)
}

fn run(
    path: &Path,
    spec: PredictorSpec,
    subtraces: usize,
    workers: usize,
    stream_window: usize,
    streaming: bool,
) -> SimReport {
    Simulation::new()
        .trace_file(path)
        .predictor(spec)
        .subtraces(subtraces)
        .workers(workers)
        .window(1_000)
        .stream_window(stream_window)
        .streaming(streaming)
        .run()
        .unwrap()
}

#[test]
fn streaming_matches_full_decode_across_modes_and_backends() {
    for (bench, n) in [("gcc", 6_000u64), ("leela", 4_000)] {
        let path = write_trace(&format!("{bench}_stream.smt"), bench, n);
        for spec in [PredictorSpec::table(16), native_fc2()] {
            // The pool row is table-only to keep the native runs cheap;
            // the streaming/full split happens before any predictor work.
            let modes: &[(usize, usize)] = if matches!(spec, PredictorSpec::Table { .. }) {
                &[(1, 1), (4, 1), (8, 2)]
            } else {
                &[(1, 1), (4, 1)]
            };
            for &(subtraces, workers) in modes {
                let s = run(&path, spec.clone(), subtraces, workers, 0, true);
                let f = run(&path, spec.clone(), subtraces, workers, 0, false);
                let tag = format!("{bench} {} s{subtraces} w{workers}", spec.label());
                assert_eq!(s.mode, f.mode, "{tag}");
                assert_eq!(s.outcome.instructions, f.outcome.instructions, "{tag}");
                assert_eq!(s.outcome.cycles, f.outcome.cycles, "{tag}");
                assert_eq!(s.outcome.windows, f.outcome.windows, "{tag}");
                assert_eq!(s.outcome.inferences, f.outcome.inferences, "{tag}");
                assert_eq!(s.des_cpi, f.des_cpi, "{tag}");
                // Only the input accounting may differ: the streamed run
                // reports its window, the full decode holds everything.
                if MmapTrace::supported() {
                    assert_eq!(s.input.window_records, DEFAULT_STREAM_WINDOW as u64, "{tag}");
                    assert!(s.input.peak_resident_records > 0, "{tag}");
                }
                assert_eq!(f.input.window_records, 0, "{tag}");
                assert_eq!(f.input.peak_resident_records, n, "{tag}");
            }
        }
    }
}

#[test]
fn window_edge_shapes_stream_identically() {
    // Window smaller than a sub-trace, window larger than the whole
    // trace, a one-record window, and a single-record trace — over a
    // 17-record length that divides into nothing.
    let odd = write_trace("odd17.smt", "xz", 17);
    let one = write_trace("one1.smt", "xz", 1);
    for (path, n, stream_window, subtraces) in
        [(&odd, 17u64, 7usize, 4usize), (&odd, 17, 4_096, 4), (&odd, 17, 1, 2), (&one, 1, 3, 1)]
    {
        let s = run(path, PredictorSpec::table(8), subtraces, 1, stream_window, true);
        let f = run(path, PredictorSpec::table(8), subtraces, 1, stream_window, false);
        let tag = format!("n={n} win={stream_window} subs={subtraces}");
        assert_eq!(s.outcome.instructions, n, "{tag}");
        assert_eq!(s.outcome.cycles, f.outcome.cycles, "{tag}");
        assert_eq!(s.outcome.windows, f.outcome.windows, "{tag}");
        if MmapTrace::supported() {
            assert_eq!(s.input.window_records, stream_window as u64, "{tag}");
        }
    }
}

#[test]
fn streamed_peak_residency_is_bounded_by_subtraces_times_window() {
    if !MmapTrace::supported() {
        return;
    }
    // A 10,000-record trace streamed through 8 sub-traces with a
    // 64-record window: the trace is >= 10x the total window budget, so
    // the bound is meaningful — a full decode holds all 10,000 records.
    let path = write_trace("peak10k.smt", "xz", 10_000);
    let report = run(&path, PredictorSpec::table(8), 8, 1, 64, true);
    assert_eq!(report.outcome.instructions, 10_000);
    assert_eq!(report.input.window_records, 64);
    let peak = report.input.peak_resident_records;
    assert!(peak > 0, "peak residency must be accounted");
    assert!(peak <= 8 * 64, "peak {peak} exceeds subtraces x window");
    // Every sub-trace is longer than the window and fully consumed, so
    // each cursor peaks at exactly one window of records.
    assert_eq!(peak, 8 * 64);
    // Sequential streaming holds at most one window at a time.
    let seq = run(&path, PredictorSpec::table(8), 1, 1, 64, true);
    let full = run(&path, PredictorSpec::table(8), 1, 1, 64, false);
    assert_eq!(seq.outcome.cycles, full.outcome.cycles);
    assert_eq!(seq.input.peak_resident_records, 64);
    assert_eq!(full.input.peak_resident_records, 10_000);
}
