//! End-to-end tests for the unified `TraceSource` input column: the
//! zero-copy mmap path and the buffered reader must produce
//! byte-identical simulations through every execution mode and
//! predictor backend, the per-source and per-session mmap switches must
//! compose, and edge-shaped traces (empty, single-record, non-aligned
//! lengths) must load identically down both paths.

use std::path::PathBuf;

use simnet::api::{ExecMode, PredictorSpec, Simulation, WeightsSource};
use simnet::des::{simulate, SimConfig};
use simnet::trace::mmap::MmapTrace;
use simnet::trace::{
    load_trace, InputStats, TraceRecord, TraceSource, TraceWriter, DEFAULT_STREAM_WINDOW,
    HEADER_SIZE, RECORD_SIZE,
};
use simnet::workload::find;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("simnet_trace_source");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// Write an `n`-instruction DES trace for `bench` and return its path.
fn write_trace(name: &str, bench: &str, n: u64) -> PathBuf {
    let path = tmp(name);
    let cfg = SimConfig::default_o3();
    let b = find(bench).unwrap();
    let mut w = TraceWriter::create(&path).unwrap();
    simulate(&cfg, b.workload(0).stream(), n, |e| {
        w.write(&TraceRecord::from(e)).unwrap();
    });
    assert_eq!(w.finish().unwrap(), n);
    path
}

fn native_fc2() -> PredictorSpec {
    PredictorSpec::native("artifacts", "fc2", 8).with_weights_source(WeightsSource::Init)
}

fn file_bytes(n: u64) -> u64 {
    (HEADER_SIZE + n as usize * RECORD_SIZE) as u64
}

#[test]
fn mmap_and_buffered_runs_are_byte_identical_across_modes() {
    for (bench, n) in [("gcc", 6_000u64), ("leela", 4_000)] {
        let path = write_trace(&format!("{bench}_modes.smt"), bench, n);
        for spec in [PredictorSpec::table(16), native_fc2()] {
            // The pool row is table-only to keep the native runs cheap;
            // the mmap/buffered split happens before any predictor work.
            let modes: &[(usize, usize, ExecMode)] =
                if matches!(spec, PredictorSpec::Table { .. }) {
                    &[(1, 1, ExecMode::Sequential), (4, 1, ExecMode::Engine), (8, 2, ExecMode::Pool)]
                } else {
                    &[(1, 1, ExecMode::Sequential), (4, 1, ExecMode::Engine)]
                };
            for &(subtraces, workers, mode) in modes {
                // Streaming off: this test pins the mmap/buffered split
                // under FULL decode (streaming identity has its own
                // matrix in tests/streaming.rs).
                let run = |mmap: bool| {
                    Simulation::new()
                        .trace_file(&path)
                        .predictor(spec.clone())
                        .subtraces(subtraces)
                        .workers(workers)
                        .window(1_000)
                        .mmap(mmap)
                        .streaming(false)
                        .run()
                        .unwrap()
                };
                let m = run(true);
                let b = run(false);
                let tag = format!("{bench} {} s{subtraces} w{workers}", spec.label());
                assert_eq!(m.mode, mode, "{tag}");
                assert_eq!(b.mode, mode, "{tag}");
                assert_eq!(m.outcome.instructions, b.outcome.instructions, "{tag}");
                assert_eq!(m.outcome.cycles, b.outcome.cycles, "{tag}");
                assert_eq!(m.outcome.windows, b.outcome.windows, "{tag}");
                assert_eq!(m.outcome.inferences, b.outcome.inferences, "{tag}");
                assert_eq!(m.des_cpi, b.des_cpi, "{tag}");
                // Each path reports its bytes in its own column; a
                // full-decode run holds every record resident.
                let total = file_bytes(n);
                let full = |mapped: u64, copied: u64| InputStats {
                    bytes_mapped: mapped,
                    bytes_copied: copied,
                    peak_resident_records: n,
                    window_records: 0,
                };
                assert_eq!(b.input, full(0, total), "{tag}");
                if MmapTrace::supported() {
                    assert_eq!(m.input, full(total, 0), "{tag}");
                } else {
                    assert_eq!(m.input, b.input, "{tag}");
                }
            }
        }
    }
}

#[test]
fn per_source_and_per_session_mmap_switches_compose() {
    let path = write_trace("compose.smt", "xz", 300);
    let total = file_bytes(300);
    let buffered = InputStats {
        bytes_mapped: 0,
        bytes_copied: total,
        peak_resident_records: 300,
        window_records: 0,
    };
    let run = |source: TraceSource<'static>, session_mmap: bool| {
        Simulation::new()
            .source(source)
            .predictor(PredictorSpec::table(8))
            .mmap(session_mmap)
            .run()
            .unwrap()
    };
    // Either switch alone forces the buffered path.
    assert_eq!(run(TraceSource::file_buffered(&path), true).input, buffered);
    assert_eq!(run(TraceSource::file(&path), false).input, buffered);
    // Both allowing: the zero-copy path, where the target supports it.
    // Streaming defaults on for mapped files, so the run reports the
    // default window, and a 300-record trace fits inside one window.
    let both = run(TraceSource::file(&path), true);
    if MmapTrace::supported() {
        assert_eq!(
            both.input,
            InputStats {
                bytes_mapped: total,
                bytes_copied: 0,
                peak_resident_records: 300,
                window_records: DEFAULT_STREAM_WINDOW as u64,
            }
        );
    } else {
        assert_eq!(both.input, buffered);
    }
    // In-memory and bench sources read no file bytes at all.
    let r = Simulation::new()
        .bench("xz", 300)
        .predictor(PredictorSpec::table(8))
        .run()
        .unwrap();
    assert_eq!(r.input, InputStats::default());
}

#[test]
fn records_source_is_zero_copy_and_matches_trace_file() {
    let path = write_trace("records_eq.smt", "xz", 800);
    let (recs, _) = load_trace(&path, true).unwrap();
    let from_records = Simulation::new()
        .records(&recs)
        .predictor(PredictorSpec::table(8))
        .window(200)
        .run()
        .unwrap();
    let from_file = Simulation::new()
        .trace_file(&path)
        .predictor(PredictorSpec::table(8))
        .window(200)
        .run()
        .unwrap();
    assert_eq!(from_records.input, InputStats::default());
    assert_eq!(from_records.outcome.cycles, from_file.outcome.cycles);
    assert_eq!(from_records.outcome.windows, from_file.outcome.windows);
    assert_eq!(from_records.des_cpi, from_file.des_cpi);
}

#[test]
fn edge_shaped_traces_load_identically_on_both_paths() {
    // Empty: a header-only 12-byte file (far below one page).
    let empty = tmp("empty.smt");
    let w = TraceWriter::create(&empty).unwrap();
    assert_eq!(w.finish().unwrap(), 0);
    // Single record, and a 17-record (1100-byte) file that is aligned to
    // nothing: record size, page size, or read-buffer size.
    let one = write_trace("one.smt", "xz", 1);
    let odd = write_trace("odd.smt", "xz", 17);
    for (path, n) in [(&empty, 0u64), (&one, 1), (&odd, 17)] {
        let (m, mstats) = load_trace(path, true).unwrap();
        let (b, bstats) = load_trace(path, false).unwrap();
        assert_eq!(m.len() as u64, n, "{}", path.display());
        assert_eq!(m, b, "{}", path.display());
        let full = |mapped: u64, copied: u64| InputStats {
            bytes_mapped: mapped,
            bytes_copied: copied,
            peak_resident_records: n,
            window_records: 0,
        };
        assert_eq!(bstats, full(0, file_bytes(n)));
        if MmapTrace::supported() {
            assert_eq!(mstats, full(file_bytes(n), 0));
        } else {
            assert_eq!(mstats, bstats);
        }
    }
}

#[test]
fn api_errors_name_the_trace_path_and_byte_offset() {
    // A missing file fails with the path in the error, whichever read
    // path was requested.
    for mmap in [true, false] {
        let err = Simulation::new()
            .trace_file("/nonexistent/zz.smt")
            .predictor(PredictorSpec::table(8))
            .mmap(mmap)
            .run()
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("zz.smt"), "mmap={mmap}: {msg}");
    }
    // Mid-record truncation is rejected at open with the byte offset,
    // identically down both paths (validation happens before mapping).
    let path = write_trace("api_truncated.smt", "xz", 2);
    let full = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(full - 10).unwrap();
    drop(f);
    let mut msgs = Vec::new();
    for mmap in [true, false] {
        let err = Simulation::new()
            .trace_file(&path)
            .predictor(PredictorSpec::table(8))
            .mmap(mmap)
            .run()
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("byte offset 76"), "mmap={mmap}: {msg}");
        msgs.push(msg);
    }
    assert_eq!(msgs[0], msgs[1], "one error-message set across both read paths");
}
