#!/usr/bin/env python3
"""Diff two SimReport JSON files, ignoring timing-derived fields.

Usage: python3 scripts/diff_reports.py A.json B.json

The job server's equivalence contract is that a daemon-run job returns
the same SimReport as a direct in-process ``Simulation::run()``. Wall
clock, MIPS, and the engine's seconds/idle fractions legitimately vary
between runs; everything else (instructions, cycles, CPI, windows,
deterministic engine stats) must match exactly. Exit 0 on match, 1 with
a per-key diff otherwise.

This is the Python twin of the ``scrubbed()`` helper in
``rust/tests/server_e2e.rs`` — keep the two key lists in sync.
"""

import json
import sys

TIMING_KEYS = ("wall_seconds", "mips")
ENGINE_TIMING_KEYS = ("encode_seconds", "predict_seconds", "engine_seconds", "predictor_idle")


def scrubbed(report):
    out = dict(report)
    for key in TIMING_KEYS:
        out.pop(key, None)
    if isinstance(out.get("engine"), dict):
        engine = dict(out["engine"])
        for key in ENGINE_TIMING_KEYS:
            engine.pop(key, None)
        out["engine"] = engine
    return out


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip().splitlines()[2], file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        a = scrubbed(json.load(f))
    with open(argv[2]) as f:
        b = scrubbed(json.load(f))
    if a == b:
        print(f"reports match ({argv[1]} == {argv[2]}, timing fields excluded)")
        return 0
    for key in sorted(set(a) | set(b)):
        if a.get(key) != b.get(key):
            print(f"MISMATCH {key}: {a.get(key)!r} != {b.get(key)!r}")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
