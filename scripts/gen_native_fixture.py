#!/usr/bin/env python3
"""Generate the tiny golden fixtures for the native Rust inference backend.

Writes, for each covered architecture (fc3, c3, rb), a miniature model
under rust/tests/fixtures/native/:

  <arch>.export      manifest (model / seq_len / batches / weights)
  <arch>.smw         weight tensors (float32, .smw container)
  <arch>.golden.txt  inputs + expected raw head rows + decoded (F,E,S)

The reference forward pass mirrors python/compile (conv1d_k2s2 = pair
reshape + matmul, residual_block, dense) but is computed in float64 from
the float32-stored weights, so the committed expectations are more
precise than either float32 implementation; the rust test compares at
1e-3. Decoding replicates rust decode_row / python decode_latency
(hybrid rule), and the generator asserts safety margins (argmax gaps,
rounding-boundary distance) so float32-vs-float64 drift cannot flip a
decoded latency.

Deterministic: fixed seeds, no timestamps. Re-running regenerates
byte-identical fixtures. Needs only numpy.
"""

import argparse
import math
import struct
from pathlib import Path

import numpy as np

NUM_FEATURES = 50
NUM_CLASSES = 10
HEAD_OUT = 3 * (NUM_CLASSES + 1)
LAT_SCALE = 256.0

# Small-but-real shapes: every layer kind, every shape-chain rule, a few
# thousand MACs per inference (fast in debug-mode `cargo test`).
MODELS = {
    "fc3": {"seq": 4, "hidden": [16, 12]},
    "c3": {"seq": 8, "chans": [6, 8, 10], "hidden": [16]},
    "rb": {"seq": 8, "chans": [6, 8, 10], "hidden": [16], "residual": True},
}


def param_specs(arch, seq):
    """Mirror of rust predictor::native::param_specs at fixture widths."""
    cfg = MODELS[arch]
    specs = []
    width, length = NUM_FEATURES, seq
    for i, c_out in enumerate(cfg.get("chans", [])):
        specs.append((f"conv{i}/w", (2 * width, c_out)))
        specs.append((f"conv{i}/b", (c_out,)))
        length //= 2
        if cfg.get("residual"):
            specs += [
                (f"res{i}/w1", (c_out, c_out)),
                (f"res{i}/b1", (c_out,)),
                (f"res{i}/w2", (c_out, c_out)),
                (f"res{i}/b2", (c_out,)),
            ]
        width = c_out
    flat = seq * NUM_FEATURES if not cfg.get("chans") else width * length
    for i, h in enumerate(cfg["hidden"]):
        specs.append((f"fc{i}/w", (flat, h)))
        specs.append((f"fc{i}/b", (h,)))
        flat = h
    specs.append(("out/w", (flat, HEAD_OUT)))
    specs.append(("out/b", (HEAD_OUT,)))
    return specs


def make_params(arch, seq, seed):
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(arch, seq):
        if len(shape) == 1:
            data = rng.normal(0.0, 0.25, size=shape)
        else:
            scale = math.sqrt(2.0 / (shape[0] + shape[-1])) * 2.0
            data = rng.normal(0.0, scale, size=shape)
        params[name] = data.astype(np.float32)
    return params


def forward(arch, params, x64):
    """Float64 reference forward over (n, seq, NUM_FEATURES) inputs."""
    cfg = MODELS[arch]
    p = {k: v.astype(np.float64) for k, v in params.items()}
    h = x64
    for i in range(len(cfg.get("chans", []))):
        n, length, c = h.shape
        pairs = h.reshape(n, length // 2, 2 * c)
        h = np.maximum(pairs @ p[f"conv{i}/w"] + p[f"conv{i}/b"], 0.0)
        if cfg.get("residual"):
            mid = np.maximum(h @ p[f"res{i}/w1"] + p[f"res{i}/b1"], 0.0)
            h = np.maximum(h + mid @ p[f"res{i}/w2"] + p[f"res{i}/b2"], 0.0)
    h = h.reshape(h.shape[0], -1)
    for i in range(len(cfg["hidden"])):
        h = np.maximum(h @ p[f"fc{i}/w"] + p[f"fc{i}/b"], 0.0)
    return h @ p["out/w"] + p["out/b"]


def decode_row(row):
    """Rust decode_row (hybrid mode), bit-for-bit at the integer level."""
    out = []
    for t in range(3):
        base = t * (NUM_CLASSES + 1)
        reg = max(row[base + NUM_CLASSES] * LAT_SCALE, 0.0)
        cls = int(np.argmax(row[base : base + NUM_CLASSES]))
        if cls < NUM_CLASSES - 1:
            out.append(cls)
        else:
            out.append(max(int(math.floor(reg + 0.5)), NUM_CLASSES - 1))
    return tuple(out)


def margins_ok(raw):
    """Reject heads where f32-vs-f64 drift could flip a decoded value."""
    for row in raw:
        for t in range(3):
            base = t * (NUM_CLASSES + 1)
            logits = np.sort(row[base : base + NUM_CLASSES])
            if logits[-1] - logits[-2] < 1e-2:  # ambiguous argmax
                return False
            reg = max(row[base + NUM_CLASSES] * LAT_SCALE, 0.0)
            frac = (reg + 0.5) % 1.0
            if not (0.01 < frac < 0.99):  # near a rounding boundary
                return False
    return True


def write_smw(path, params):
    with open(path, "wb") as f:
        f.write(b"SMW1")
        f.write(struct.pack("<I", len(params)))
        for name, data in params.items():
            enc = name.encode()
            f.write(struct.pack("<H", len(enc)) + enc)
            f.write(struct.pack("<I", data.ndim))
            for d in data.shape:
                f.write(struct.pack("<I", d))
            f.write(data.astype("<f4").tobytes())


def fmt(values):
    return " ".join(f"{float(v):.9g}" for v in values)


def gen_model(arch, out_dir):
    seq = MODELS[arch]["seq"]
    n = 3
    # Search a deterministic seed range for one where every decoded value
    # sits safely away from argmax ties and rounding boundaries, and both
    # decode paths (class hit and ">8" regression fallback) occur.
    for seed in range(64):
        params = make_params(arch, seq, seed)
        rng = np.random.default_rng(1000 + seed)
        x = rng.uniform(0.0, 1.0, size=(n, seq, NUM_FEATURES))
        x[rng.random(x.shape) < 0.5] = 0.0  # exercise the zero-skip path
        x = x.astype(np.float32)
        raw = forward(arch, params, x.astype(np.float64))
        classes = [
            int(np.argmax(row[t * 11 : t * 11 + NUM_CLASSES])) for row in raw for t in range(3)
        ]
        has_reg = any(c == NUM_CLASSES - 1 for c in classes)
        has_cls = any(c < NUM_CLASSES - 1 for c in classes)
        if margins_ok(raw) and has_reg and has_cls:
            break
    else:
        raise SystemExit(f"{arch}: no safe seed found")
    fes = [decode_row(row) for row in raw]

    write_smw(out_dir / f"{arch}.smw", params)
    names = " ".join(params.keys())
    (out_dir / f"{arch}.export").write_text(
        f"model {arch}\nseq_len {seq}\nbatches 1 {n}\nweights {names}\n"
    )
    lines = [f"model {arch}", f"seq {seq}", f"n {n}"]
    lines += [f"input {fmt(row.reshape(-1))}" for row in x]
    lines += [f"raw {fmt(row)}" for row in raw]
    lines += [f"fes {f} {e} {s}" for (f, e, s) in fes]
    (out_dir / f"{arch}.golden.txt").write_text("\n".join(lines) + "\n")
    params_total = sum(v.size for v in params.values())
    print(f"{arch}: seed={seed} seq={seq} params={params_total} fes={fes}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    default_out = Path(__file__).resolve().parent.parent / "rust/tests/fixtures/native"
    ap.add_argument("--out", type=Path, default=default_out)
    args = ap.parse_args()
    args.out.mkdir(parents=True, exist_ok=True)
    for arch in MODELS:
        gen_model(arch, args.out)


if __name__ == "__main__":
    main()
