#!/usr/bin/env bash
# Check that relative markdown links in the repo's hand-written docs
# resolve to real files, so docs/ARCHITECTURE.md and README.md cannot
# silently rot as the source tree moves underneath them. External
# (http/https/mailto) links and pure #fragment anchors are skipped.
#
# Usage: bash scripts/check_docs.sh   (run from anywhere; CI runs it in
# the docs job after `cargo doc`).
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
docs=("$repo_root/README.md" "$repo_root/docs/ARCHITECTURE.md")

fail=0
for doc in "${docs[@]}"; do
    if [[ ! -f "$doc" ]]; then
        echo "MISSING DOC: $doc"
        fail=1
        continue
    fi
    dir="$(dirname "$doc")"
    # Extract [text](target) markdown links, one target per line.
    # grep exits 1 on no matches; that just means nothing to check.
    targets="$(grep -oE '\]\([^)]+\)' "$doc" | sed -E 's/^\]\(//; s/\)$//')" || true
    while IFS= read -r target; do
        [[ -z "$target" ]] && continue
        case "$target" in
            http://*|https://*|mailto:*|\#*) continue ;;
        esac
        path="${target%%#*}"   # drop any #fragment
        [[ -z "$path" ]] && continue
        if [[ ! -e "$dir/$path" ]]; then
            echo "BROKEN LINK in ${doc#"$repo_root"/}: ($target) -> $dir/$path"
            fail=1
        fi
    done <<< "$targets"
done

if [[ "$fail" -ne 0 ]]; then
    echo "check_docs: broken links found"
    exit 1
fi
echo "check_docs: all relative links resolve"
