#!/usr/bin/env python3
"""Compare a bench JSON report against a committed baseline.

Usage: compare_bench.py CURRENT.json BASELINE.json [--max-regression 2.0]

For every config named in the baseline, the current MIPS must be at least
``baseline_mips / max_regression``. The threshold is deliberately generous
(default 2x) so CI-runner noise does not flake the gate; it exists to
catch order-of-magnitude regressions in the engine hot path, and to be
ratcheted tighter as baselines firm up. Configs present in the current
report but not in the baseline are informational only.
"""

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON produced by bench_engine --json")
    ap.add_argument("baseline", help="committed baseline JSON (bench/baseline.json)")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=2.0,
        help="fail when current MIPS < baseline / this factor (default 2.0)",
    )
    args = ap.parse_args()

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    current_by_name = {c["name"]: c for c in current.get("configs", [])}
    failures = []
    matched = 0
    for base_cfg in baseline.get("configs", []):
        name = base_cfg["name"]
        cur = current_by_name.get(name)
        if cur is None:
            print(f"[warn] baseline config {name!r} missing from current results")
            continue
        matched += 1
        floor = base_cfg["mips"] / args.max_regression
        ok = cur["mips"] >= floor
        status = "ok" if ok else "REGRESSION"
        print(
            f"{name}: current {cur['mips']:.3f} MIPS vs baseline "
            f"{base_cfg['mips']:.3f} (floor {floor:.3f}) -> {status}"
        )
        if not ok:
            failures.append(name)

    extra = sorted(set(current_by_name) - {c["name"] for c in baseline.get("configs", [])})
    if extra:
        print(f"[info] configs without a baseline: {', '.join(extra)}")
    speedup = current.get("threaded_speedup")
    if speedup is not None:
        print(f"[info] threaded speedup over serial: {speedup:.2f}x")

    if matched == 0 and baseline.get("configs"):
        # A rename of the sweep configs must not silently disable the gate.
        print(
            "FAIL: no baseline config matched the current report — "
            "update bench/baseline.json to the new config names",
            file=sys.stderr,
        )
        return 1
    if failures:
        print(
            f"FAIL: regression beyond {args.max_regression}x on: {', '.join(failures)}",
            file=sys.stderr,
        )
        return 1
    print("bench within regression threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
