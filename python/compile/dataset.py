"""Reader for the `.smd` ML dataset format produced by `repro build-dataset`.

Layout (little-endian, see rust/src/trace/mod.rs):
    magic "SMD1" | u32 seq_len | u32 nfeat | u64 nsamples
    nsamples x [seq_len * nfeat f32 features, 3 f32 labels]

Samples are exposed as a numpy memmap so multi-hundred-MB datasets never
need to be resident: training gathers batches by index.
"""

import struct

import numpy as np

MAGIC = b"SMD1"
HEADER = 20
NUM_LABELS = 3


class Dataset:
    """Memory-mapped (features, labels) sample store with a 90/5/5 split
    (paper §2.4: 90% training, 5% validation, 5% testing)."""

    def __init__(self, path):
        with open(path, "rb") as f:
            head = f.read(HEADER)
        assert head[:4] == MAGIC, f"{path} is not an .smd dataset"
        self.seq_len, self.nfeat = struct.unpack("<II", head[4:12])
        (self.n,) = struct.unpack("<Q", head[12:20])
        row = self.seq_len * self.nfeat + NUM_LABELS
        self._mm = np.memmap(path, dtype="<f4", mode="r", offset=HEADER, shape=(self.n, row))
        # Deterministic shuffled split.
        rng = np.random.default_rng(0xDA7A)
        self._perm = rng.permutation(self.n)
        n_train = int(self.n * 0.9)
        n_val = int(self.n * 0.05)
        self._splits = {
            "train": self._perm[:n_train],
            "val": self._perm[n_train : n_train + n_val],
            "test": self._perm[n_train + n_val :],
        }

    def split_size(self, split):
        return len(self._splits[split])

    def batch(self, split, idx, batch_size):
        """Batch `idx` of `split`: (features (B, seq, nfeat), labels (B, 3))."""
        ids = self._splits[split][idx * batch_size : (idx + 1) * batch_size]
        rows = self._mm[np.sort(ids)]
        feats = rows[:, : self.seq_len * self.nfeat].reshape(-1, self.seq_len, self.nfeat)
        labels = rows[:, self.seq_len * self.nfeat :]
        return np.ascontiguousarray(feats), np.ascontiguousarray(labels)

    def batches(self, split, batch_size, limit=None):
        n = self.split_size(split) // batch_size
        if limit:
            n = min(n, limit)
        for i in range(n):
            yield self.batch(split, i, batch_size)
