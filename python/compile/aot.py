"""AOT export: lower each model's Pallas-kernel forward pass to HLO text.

The interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

One artifact per (model, batch size): `artifacts/<model>_b<B>.hlo.txt`.
The executable's arguments are [weights..., x] in `param_specs` order, so
the rust runtime can load any `.smw` whose tensor order matches — weights
are runtime inputs, never baked constants, which is what lets the §5
config studies retrain without re-exporting.

Usage:
    python -m compile.aot --out ../artifacts [--models c3,rb] [--seq 32]
"""

import argparse
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .smw import write_smw

DEFAULT_BATCHES = (1, 8, 64, 256)


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(model_name, seq, out_dir, batches=DEFAULT_BATCHES, quiet=False):
    """Lower `model_name` at each batch size; write HLO text + init .smw."""
    os.makedirs(out_dir, exist_ok=True)
    specs = M.param_specs(model_name, seq)
    names = [n for n, _ in specs]

    def fwd(*args):
        ws = dict(zip(names, args[:-1]))
        x = args[-1]
        return (M.apply(model_name, ws, x, use_pallas=True),)

    written = []
    for b in batches:
        arg_shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
        arg_shapes.append(jax.ShapeDtypeStruct((b, seq, M.NUM_FEATURES), jnp.float32))
        lowered = jax.jit(fwd).lower(*arg_shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{model_name}_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        if not quiet:
            print(f"[aot] {model_name} b={b}: {len(text)} chars -> {path}")

    # Untrained init weights so the runtime can execute before training.
    init_path = os.path.join(out_dir, f"{model_name}.init.smw")
    params = M.init_params(model_name, seq)
    write_smw(init_path, [(n, np.asarray(params[n])) for n in names])

    # Export manifest for the rust runtime (plain text, no JSON dep).
    with open(os.path.join(out_dir, f"{model_name}.export"), "w") as f:
        f.write(f"model {model_name}\nseq_len {seq}\n")
        f.write("batches " + " ".join(str(b) for b in batches) + "\n")
        f.write("weights " + " ".join(names) + "\n")
    return written


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="c3,rb,fc3,lstm2,ithemal_lstm2")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batches", default=",".join(str(b) for b in DEFAULT_BATCHES))
    args = ap.parse_args()
    batches = tuple(int(b) for b in args.batches.split(","))
    for m in args.models.split(","):
        export_model(m.strip(), args.seq, args.out, batches)


if __name__ == "__main__":
    main()
