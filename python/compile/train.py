"""Training loop for the latency-predictor model zoo (paper §2.4).

Standard supervised training on `.smd` datasets produced by
`repro build-dataset`. The objective follows the paper: cross-entropy on
the per-latency class heads (cycles 0..8 + ">8") plus squared error on the
regression heads, Adam, lr 1e-3, no weight decay. A `--output reg`
variant trains the regression heads only (the Table 4 "reg" rows).

Runs once at build time (never on the simulation path) and writes the
trained weights to `artifacts/<model>.smw` plus a small text meta file the
rust runtime parses.

Usage:
    python -m compile.train --dataset ../artifacts/train.smd --model c3 \
        --epochs 4 --out ../artifacts
"""

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M
from .dataset import Dataset
from .smw import write_smw


def hybrid_loss(outputs, labels, mode="hyb"):
    """Loss over the 33-way head for labels (B, 3) raw cycles."""
    total = 0.0
    for t in range(3):
        base = t * (M.NUM_CLASSES + 1)
        logits = outputs[:, base : base + M.NUM_CLASSES]
        reg = outputs[:, base + M.NUM_CLASSES]
        lat = labels[:, t]
        cls = jnp.minimum(lat, M.NUM_CLASSES - 1).astype(jnp.int32)
        reg_target = lat / M.LAT_SCALE
        mse = jnp.mean((reg - reg_target) ** 2)
        if mode == "reg":
            total = total + mse
        else:
            logp = jax.nn.log_softmax(logits, axis=-1)
            ce = -jnp.mean(jnp.take_along_axis(logp, cls[:, None], axis=1))
            total = total + ce + mse
    return total


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def prediction_error(outputs, labels, mode="hyb"):
    """Paper §2.5 error metric per latency type: |pred - y| / (y + 1)."""
    if mode == "reg":
        # regression decode only
        pred = jnp.stack(
            [
                jnp.maximum(outputs[:, t * (M.NUM_CLASSES + 1) + M.NUM_CLASSES], 0.0)
                * M.LAT_SCALE
                for t in range(3)
            ],
            axis=-1,
        )
    else:
        pred = M.decode_latency(outputs)
    return jnp.mean(jnp.abs(pred - labels) / (labels + 1.0), axis=0)


def train(
    dataset_path,
    model_name,
    out_dir,
    epochs=4,
    batch_size=256,
    lr=1e-3,
    seed=0,
    mode="hyb",
    max_steps=0,
    cfg_tag="",
    quiet=False,
):
    """Train one model; returns (params, test_errors (3,), history)."""
    ds = Dataset(dataset_path)
    seq = ds.seq_len
    params = {k: jnp.asarray(v) for k, v in M.init_params(model_name, seq, seed).items()}

    def loss_fn(p, x, y):
        out = M.apply(model_name, p, x, use_pallas=False)
        return hybrid_loss(out, y, mode)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    apply_jit = jax.jit(lambda p, x: M.apply(model_name, p, x, use_pallas=False))

    opt = adam_init(params)
    steps_per_epoch = max(1, ds.split_size("train") // batch_size)
    if max_steps:
        steps_per_epoch = min(steps_per_epoch, max_steps)
    history = []
    best_val = float("inf")
    best_params = params
    t0 = time.time()
    for epoch in range(epochs):
        for i in range(steps_per_epoch):
            x, y = ds.batch("train", i, batch_size)
            loss, grads = grad_fn(params, jnp.asarray(x), jnp.asarray(y))
            params, opt = adam_update(params, grads, opt, lr=lr)
        # Validation (paper: val set selects the best checkpoint).
        vloss = 0.0
        vn = 0
        for x, y in ds.batches("val", batch_size, limit=20):
            vloss += float(loss_fn(params, jnp.asarray(x), jnp.asarray(y)))
            vn += 1
        vloss /= max(vn, 1)
        history.append(vloss)
        if vloss < best_val:
            best_val = vloss
            best_params = params
        if not quiet:
            print(
                f"[train] {model_name} epoch {epoch + 1}/{epochs} "
                f"val_loss={vloss:.4f} ({time.time() - t0:.0f}s)"
            )
    params = best_params

    # Test-set prediction error (Table 4 middle columns).
    errs = np.zeros(3)
    n = 0
    for x, y in ds.batches("test", batch_size, limit=40):
        out = apply_jit(params, jnp.asarray(x))
        errs += np.asarray(prediction_error(out, jnp.asarray(y), mode))
        n += 1
    errs /= max(n, 1)

    train_seconds = time.time() - t0
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{model_name}{cfg_tag}"
        names = [name for name, _ in M.param_specs(model_name, seq)]
        write_smw(
            os.path.join(out_dir, f"{tag}.smw"),
            [(name, np.asarray(params[name])) for name in names],
        )
        with open(os.path.join(out_dir, f"{tag}.meta"), "w") as f:
            f.write(f"model {model_name}\nseq_len {seq}\nmode {mode}\n")
            f.write(f"fetch_err {errs[0]:.6f}\nexec_err {errs[1]:.6f}\nstore_err {errs[2]:.6f}\n")
            f.write(f"mflops {M.flops(model_name, seq):.3f}\n")
            f.write(f"train_seconds {train_seconds:.1f}\n")
        if not quiet:
            print(
                f"[train] {tag}: fetch/exec/store err = "
                f"{errs[0]:.3f}/{errs[1]:.3f}/{errs[2]:.3f} -> {out_dir}/{tag}.smw"
            )
    return params, errs, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", required=True)
    ap.add_argument("--model", default="c3", choices=M.MODELS)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--output", dest="mode", default="hyb", choices=["hyb", "reg"])
    ap.add_argument("--max-steps", type=int, default=0, help="cap steps/epoch (CI)")
    ap.add_argument("--cfg-tag", default="", help="suffix for config studies, e.g. _rob")
    args = ap.parse_args()
    train(
        args.dataset,
        args.model,
        args.out,
        epochs=args.epochs,
        batch_size=args.batch_size,
        lr=args.lr,
        seed=args.seed,
        mode=args.mode,
        max_steps=args.max_steps,
        cfg_tag=args.cfg_tag,
    )


if __name__ == "__main__":
    main()
