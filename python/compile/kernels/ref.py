"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness baseline: every Pallas kernel in this
package must match its `*_ref` twin bit-for-bit (up to float tolerance)
under pytest. Training also runs through these functions (they are plain
differentiable jnp), while AOT export runs through the Pallas versions —
the pytest equivalence is what licenses that swap.
"""

import jax.numpy as jnp


def conv1d_k2s2_ref(x, w, b):
    """Hierarchical convolution layer, kernel size 2, stride 2 (paper §2.3).

    SimNet's CNN design principles: non-overlapping inputs, kernel and
    stride fixed at 2, so each layer halves the sequence and each context
    instruction's influence is integrated exactly once.

    Args:
      x: (B, L, C) input sequence (L even).
      w: (2 * C, C2) fused pair weights.
      b: (C2,) bias.
    Returns:
      (B, L // 2, C2) activations after ReLU.
    """
    B, L, C = x.shape
    pairs = x.reshape(B, L // 2, 2 * C)
    y = jnp.einsum("blc,cd->bld", pairs, w) + b
    return jnp.maximum(y, 0.0)


def dense_ref(x, w, b, relu=True):
    """Fully connected layer: (B, D) @ (D, H) + b, optional ReLU."""
    y = x @ w + b
    return jnp.maximum(y, 0.0) if relu else y


def residual_block_ref(x, w1, b1, w2, b2):
    """Width-preserving residual block (paper Fig. 2 bottom, RB models).

    Two per-position transforms with a skip connection, EfficientNet style
    but without squeeze-excite:  y = relu(x + W2 @ relu(W1 @ x)).

    Args:
      x: (B, L, C); w1, w2: (C, C); b1, b2: (C,).
    """
    h = jnp.maximum(jnp.einsum("blc,cd->bld", x, w1) + b1, 0.0)
    h = jnp.einsum("blc,cd->bld", h, w2) + b2
    return jnp.maximum(x + h, 0.0)
