"""Pallas kernel: the hierarchical k=2/s=2 convolution (paper §2.3).

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper runs this
convolution through TensorRT on A100 tensor cores (im2col + WMMA tiles).
On TPU the same layer is better expressed as a *pairs-matmul*: because the
kernel size equals the stride (2), the convolution is exactly

    y[b, l, :] = relu(concat(x[b, 2l, :], x[b, 2l+1, :]) @ W + bias)

i.e. one dense (B * L/2, 2C) x (2C, C2) matmul — a single MXU-shaped
contraction per layer with no gather/im2col, no halo exchange. The
BlockSpec tiles the batch dimension so each grid step works on a
(BLOCK_B, L, C) panel resident in VMEM, with the full weight panel
broadcast to every step — the HBM<->VMEM schedule that threadblocks
expressed on the GPU.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the rust runtime. Real-TPU perf is *estimated* from
the VMEM footprint / MXU utilization in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: chosen so a (BLOCK_B, L, C) input panel + (2C, C2) weights +
# (BLOCK_B, L/2, C2) output stay well under ~4 MiB of VMEM for every layer
# geometry in the model zoo (see vmem_bytes()).
BLOCK_B = 32


def _conv_kernel(x_ref, w_ref, b_ref, o_ref):
    """One grid step: pairs-matmul over a VMEM-resident batch tile."""
    x = x_ref[...]  # (bb, L, C)
    bb, L, C = x.shape
    pairs = x.reshape(bb, L // 2, 2 * C)
    y = jax.lax.dot_general(
        pairs,
        w_ref[...],
        dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.maximum(y + b_ref[...], 0.0)


def conv1d_k2s2(x, w, b, *, block_b=BLOCK_B):
    """Pallas pairs-matmul convolution; matches `ref.conv1d_k2s2_ref`.

    Args:
      x: (B, L, C), L even; B padded internally to a multiple of block_b.
      w: (2 * C, C2); b: (C2,).
    Returns:
      (B, L // 2, C2).
    """
    B, L, C = x.shape
    C2 = w.shape[1]
    assert L % 2 == 0, f"sequence length {L} must be even"
    assert w.shape[0] == 2 * C, f"weight rows {w.shape[0]} != 2*C={2 * C}"
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    padded_b = x.shape[0]
    out = pl.pallas_call(
        _conv_kernel,
        grid=(padded_b // bb,),
        in_specs=[
            pl.BlockSpec((bb, L, C), lambda i: (i, 0, 0)),
            pl.BlockSpec((2 * C, C2), lambda i: (0, 0)),
            pl.BlockSpec((C2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, L // 2, C2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, L // 2, C2), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:B]


def vmem_bytes(block_b, L, C, C2):
    """Estimated VMEM working set of one grid step, in bytes (f32).

    Used by DESIGN.md §Perf to check each layer stays under the ~16 MiB
    VMEM budget of a TPU core (target: <= 4 MiB so double-buffering fits).
    """
    x_tile = block_b * L * C * 4
    w_tile = 2 * C * C2 * 4
    o_tile = block_b * (L // 2) * C2 * 4
    return x_tile + w_tile + o_tile


def mxu_utilization(L, C, C2):
    """Fraction of MXU (128x128) lanes used by the pairs-matmul shapes.

    The contraction is (rows, 2C) @ (2C, C2): utilization is limited by how
    well 2C and C2 fill the 128-wide systolic dimensions.
    """
    k = min(2 * C, 128) / 128.0
    n = min(C2, 128) / 128.0
    return k * n


@functools.partial(jax.jit, static_argnames=("block_b",))
def conv1d_k2s2_jit(x, w, b, block_b=BLOCK_B):
    """jit wrapper used by tests."""
    return conv1d_k2s2(x, w, b, block_b=block_b)
