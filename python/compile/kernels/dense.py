"""Pallas kernel: fused dense layer (matmul + bias + optional ReLU).

Used for the fully connected tail of every model (paper Fig. 2: "FC
layers") and for the FC2/FC3 baselines. Tiled over the batch dimension
like conv1d; the weight panel is broadcast to every grid step. The hidden
sizes in the model zoo (<= 1024) keep a full (D, H) weight panel + a
(BLOCK_B, D) activation tile comfortably inside VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 64


def _dense_kernel_relu(x_ref, w_ref, b_ref, o_ref):
    y = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.maximum(y + b_ref[...], 0.0)


def _dense_kernel_linear(x_ref, w_ref, b_ref, o_ref):
    y = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = y + b_ref[...]


def dense(x, w, b, relu=True, *, block_b=BLOCK_B):
    """Pallas fused dense layer; matches `ref.dense_ref`.

    Args:
      x: (B, D); w: (D, H); b: (H,).
    Returns:
      (B, H).
    """
    B, D = x.shape
    H = w.shape[1]
    assert w.shape[0] == D, f"weight rows {w.shape[0]} != D={D}"
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    padded_b = x.shape[0]
    kernel = _dense_kernel_relu if relu else _dense_kernel_linear
    out = pl.pallas_call(
        kernel,
        grid=(padded_b // bb,),
        in_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((D, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, H), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:B]


def vmem_bytes(block_b, D, H):
    """Estimated VMEM working set of one grid step (f32 bytes)."""
    return (block_b * D + D * H + block_b * H) * 4


@functools.partial(jax.jit, static_argnames=("relu", "block_b"))
def dense_jit(x, w, b, relu=True, block_b=BLOCK_B):
    return dense(x, w, b, relu=relu, block_b=block_b)
