"""L2: the SimNet latency-predictor model zoo (paper §2.3, Table 4).

Models (names match Table 4 rows):
  fc2, fc3        fully connected baselines
  c1, c3          conventional CNNs (kernel 2, stride 2 hierarchy)
  rb              residual-block CNN (the paper's RB7, EfficientNet-style)
  lstm2           sequence LSTM (SimNet-featured)
  ithemal_lstm2   same architecture, Ithemal-style fixed-window features
                  (the feature difference lives on the rust side)
  tx2             small Transformer encoder (the paper's TX6, scaled)

Every model maps a (B, SEQ, 50) feature tensor to a (B, 33) hybrid head:
for each of the three latencies (fetch, execution, store) it emits 10
class logits (cycles 0..8 plus a ">8" class) and 1 regression value in
LAT_SCALE units (paper §2.3 "From Output to Latency").

`apply(..., use_pallas=True)` routes the convolution/dense hot-spots
through the Pallas kernels (what gets AOT-exported); `use_pallas=False`
uses the pure-jnp references (differentiable, used for training). pytest
asserts both paths agree.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import conv1d, dense as dense_k, ref

# Feature contract shared with rust/src/features/mod.rs.
NUM_FEATURES = 50
# Hybrid head: classes 0..8 + ">8" per latency type.
NUM_CLASSES = 10
HEAD_OUT = 3 * (NUM_CLASSES + 1)
# Latency normalization (rust features::LAT_SCALE).
LAT_SCALE = 256.0

MODELS = ("fc2", "fc3", "c1", "c3", "rb", "lstm2", "ithemal_lstm2", "tx2")

# ----------------------------------------------------------------------
# Parameter construction
# ----------------------------------------------------------------------


def _glorot(rng, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    return rng.normal(0.0, scale, size=shape).astype(np.float32)


def _conv_spec(seq, chans):
    """(name, shape) params for a k2s2 conv stack over `chans` widths."""
    specs = []
    c_in = NUM_FEATURES
    length = seq
    for i, c_out in enumerate(chans):
        specs.append((f"conv{i}/w", (2 * c_in, c_out)))
        specs.append((f"conv{i}/b", (c_out,)))
        c_in = c_out
        length //= 2
    return specs, c_in * length


def param_specs(model, seq):
    """Ordered (name, shape) list for a model; order == HLO arg order."""
    if model == "fc2":
        d = seq * NUM_FEATURES
        return [
            ("fc0/w", (d, 256)), ("fc0/b", (256,)),
            ("out/w", (256, HEAD_OUT)), ("out/b", (HEAD_OUT,)),
        ]
    if model == "fc3":
        d = seq * NUM_FEATURES
        return [
            ("fc0/w", (d, 512)), ("fc0/b", (512,)),
            ("fc1/w", (512, 256)), ("fc1/b", (256,)),
            ("out/w", (256, HEAD_OUT)), ("out/b", (HEAD_OUT,)),
        ]
    if model == "c1":
        specs, flat = _conv_spec(seq, [64])
        return specs + [
            ("fc0/w", (flat, 256)), ("fc0/b", (256,)),
            ("out/w", (256, HEAD_OUT)), ("out/b", (HEAD_OUT,)),
        ]
    if model == "c3":
        specs, flat = _conv_spec(seq, [64, 96, 128])
        return specs + [
            ("fc0/w", (flat, 256)), ("fc0/b", (256,)),
            ("out/w", (256, HEAD_OUT)), ("out/b", (HEAD_OUT,)),
        ]
    if model == "rb":
        # 7 learned stages: conv64, res64, conv96, res96, conv128, res128,
        # then the FC tail — the paper's RB7 shape at our scale.
        specs = []
        c_in = NUM_FEATURES
        length = seq
        for i, c_out in enumerate([64, 96, 128]):
            specs += [(f"conv{i}/w", (2 * c_in, c_out)), (f"conv{i}/b", (c_out,))]
            length //= 2
            specs += [
                (f"res{i}/w1", (c_out, c_out)), (f"res{i}/b1", (c_out,)),
                (f"res{i}/w2", (c_out, c_out)), (f"res{i}/b2", (c_out,)),
            ]
            c_in = c_out
        flat = c_in * length
        return specs + [
            ("fc0/w", (flat, 256)), ("fc0/b", (256,)),
            ("out/w", (256, HEAD_OUT)), ("out/b", (HEAD_OUT,)),
        ]
    if model in ("lstm2", "ithemal_lstm2"):
        h = 128
        specs = []
        d = NUM_FEATURES
        for layer in range(2):
            specs += [
                (f"lstm{layer}/wx", (d, 4 * h)),
                (f"lstm{layer}/wh", (h, 4 * h)),
                (f"lstm{layer}/b", (4 * h,)),
            ]
            d = h
        return specs + [("out/w", (h, HEAD_OUT)), ("out/b", (HEAD_OUT,))]
    if model == "tx2":
        d = 64
        specs = [("embed/w", (NUM_FEATURES, d)), ("embed/b", (d,))]
        for layer in range(2):
            specs += [
                (f"attn{layer}/wq", (d, d)), (f"attn{layer}/wk", (d, d)),
                (f"attn{layer}/wv", (d, d)), (f"attn{layer}/wo", (d, d)),
                (f"ffn{layer}/w1", (d, 128)), (f"ffn{layer}/b1", (128,)),
                (f"ffn{layer}/w2", (128, d)), (f"ffn{layer}/b2", (d,)),
            ]
        return specs + [("out/w", (d, HEAD_OUT)), ("out/b", (HEAD_OUT,))]
    raise ValueError(f"unknown model {model!r}")


def init_params(model, seq, seed=0):
    """Deterministic parameter init; returns an ordered dict name -> array."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(model, seq):
        if name.endswith("/b") or name.endswith("/b1") or name.endswith("/b2"):
            params[name] = np.zeros(shape, dtype=np.float32)
        else:
            params[name] = _glorot(rng, shape)
    return params


# ----------------------------------------------------------------------
# Forward passes
# ----------------------------------------------------------------------


def _conv_layer(x, w, b, use_pallas):
    if use_pallas:
        return conv1d.conv1d_k2s2(x, w, b)
    return ref.conv1d_k2s2_ref(x, w, b)


def _dense_layer(x, w, b, relu, use_pallas):
    if use_pallas:
        return dense_k.dense(x, w, b, relu=relu)
    return ref.dense_ref(x, w, b, relu=relu)


def _lstm_layer(x, wx, wh, b):
    """Single LSTM layer over (B, T, D) -> (B, T, H), plain jnp."""
    B, T, _ = x.shape
    h_dim = wh.shape[0]

    def cell(carry, xt):
        h, c = carry
        z = xt @ wx + h @ wh + b
        i, f, g, o = jnp.split(z, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c = f * c + i * g
        h = o * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, h_dim)), jnp.zeros((B, h_dim)))
    # Scan over time: x transposed to (T, B, D).
    (_, _), hs = jax.lax.scan(cell, init, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def apply(model, params, x, use_pallas=False):
    """Forward pass: x (B, SEQ, 50) -> (B, 33) hybrid head outputs."""
    p = params
    if model in ("fc2", "fc3"):
        B = x.shape[0]
        h = x.reshape(B, -1)
        h = _dense_layer(h, p["fc0/w"], p["fc0/b"], True, use_pallas)
        if model == "fc3":
            h = _dense_layer(h, p["fc1/w"], p["fc1/b"], True, use_pallas)
        return _dense_layer(h, p["out/w"], p["out/b"], False, use_pallas)

    if model in ("c1", "c3"):
        chans = 1 if model == "c1" else 3
        h = x
        for i in range(chans):
            h = _conv_layer(h, p[f"conv{i}/w"], p[f"conv{i}/b"], use_pallas)
        B = h.shape[0]
        h = h.reshape(B, -1)
        h = _dense_layer(h, p["fc0/w"], p["fc0/b"], True, use_pallas)
        return _dense_layer(h, p["out/w"], p["out/b"], False, use_pallas)

    if model == "rb":
        h = x
        for i in range(3):
            h = _conv_layer(h, p[f"conv{i}/w"], p[f"conv{i}/b"], use_pallas)
            h = ref.residual_block_ref(
                h, p[f"res{i}/w1"], p[f"res{i}/b1"], p[f"res{i}/w2"], p[f"res{i}/b2"]
            )
        B = h.shape[0]
        h = h.reshape(B, -1)
        h = _dense_layer(h, p["fc0/w"], p["fc0/b"], True, use_pallas)
        return _dense_layer(h, p["out/w"], p["out/b"], False, use_pallas)

    if model in ("lstm2", "ithemal_lstm2"):
        # Feed oldest -> newest so the recurrent state ends on the current
        # instruction (slot 0 is the current one in the rust encoding).
        h = x[:, ::-1, :]
        for layer in range(2):
            h = _lstm_layer(
                h, p[f"lstm{layer}/wx"], p[f"lstm{layer}/wh"], p[f"lstm{layer}/b"]
            )
        last = h[:, -1, :]
        return _dense_layer(last, p["out/w"], p["out/b"], False, use_pallas)

    if model == "tx2":
        d = p["embed/w"].shape[1]
        h = jnp.einsum("blf,fd->bld", x, p["embed/w"]) + p["embed/b"]
        for layer in range(2):
            q = jnp.einsum("bld,de->ble", h, p[f"attn{layer}/wq"])
            k = jnp.einsum("bld,de->ble", h, p[f"attn{layer}/wk"])
            v = jnp.einsum("bld,de->ble", h, p[f"attn{layer}/wv"])
            a = jax.nn.softmax(jnp.einsum("ble,bme->blm", q, k) / np.sqrt(d), axis=-1)
            att = jnp.einsum("blm,bme->ble", a, v)
            h = h + jnp.einsum("ble,ed->bld", att, p[f"attn{layer}/wo"])
            f = jnp.maximum(
                jnp.einsum("bld,dh->blh", h, p[f"ffn{layer}/w1"]) + p[f"ffn{layer}/b1"], 0.0
            )
            h = h + jnp.einsum("blh,hd->bld", f, p[f"ffn{layer}/w2"]) + p[f"ffn{layer}/b2"]
        cur = h[:, 0, :]  # the to-be-predicted instruction's token
        return _dense_layer(cur, p["out/w"], p["out/b"], False, use_pallas)

    raise ValueError(f"unknown model {model!r}")


# ----------------------------------------------------------------------
# Hybrid head decode + analytic compute intensity
# ----------------------------------------------------------------------


def decode_latency(outputs):
    """Vectorized hybrid decode (paper §2.3): per latency type, take the
    argmax class; classes 0..8 mean that many cycles, class 9 (">8") falls
    back to the regression output. Returns (B, 3) float latencies.

    The rust runtime implements the identical rule in predictor/mod.rs.
    """
    outs = []
    for t in range(3):
        base = t * (NUM_CLASSES + 1)
        logits = outputs[:, base : base + NUM_CLASSES]
        reg = outputs[:, base + NUM_CLASSES] * LAT_SCALE
        cls = jnp.argmax(logits, axis=-1)
        lat = jnp.where(cls < NUM_CLASSES - 1, cls.astype(jnp.float32), jnp.maximum(reg, 9.0))
        outs.append(lat)
    return jnp.stack(outs, axis=-1)


def flops(model, seq):
    """Millions of multiplies per single-instruction inference (Table 4's
    "computation intensity" column), computed analytically from shapes."""
    total = 0
    for name, shape in param_specs(model, seq):
        if name.endswith("/b") or name.endswith("/b1") or name.endswith("/b2"):
            continue
        if name.startswith("conv"):
            c2 = shape[1]
            # applied at every output position of its layer
            layer = int(name[4])
            positions = seq // (2 ** (layer + 1))
            total += shape[0] * c2 * positions
        elif name.startswith("res"):
            layer = int(name[3])
            positions = seq // (2 ** (layer + 1))
            total += shape[0] * shape[1] * positions
        elif name.startswith("lstm"):
            total += shape[0] * shape[1] * seq
        elif name.startswith(("attn", "ffn", "embed")):
            total += shape[0] * shape[1] * seq
        else:  # fc
            total += shape[0] * shape[1]
    if model == "tx2":
        total += 2 * 2 * seq * seq * 64  # attention scores + weighted sum
    return total / 1e6
