"""Python side of the `.smw` weight-tensor container.

Mirror of rust/src/tensor/mod.rs — keep the two in sync.
"""

import struct

MAGIC = b"SMW1"


def write_smw(path, tensors):
    """Write an ordered list of (name, np.float32 array) pairs."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            data = arr.astype("<f4", copy=False)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", data.ndim))
            for d in data.shape:
                f.write(struct.pack("<I", d))
            f.write(data.tobytes(order="C"))


def read_smw(path):
    """Read back an ordered list of (name, np.float32 array) pairs."""
    import numpy as np

    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path} is not an .smw file"
        (count,) = struct.unpack("<I", f.read(4))
        out = []
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(shape)) if ndim else 1
            arr = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(shape)
            out.append((name, arr))
        return out
