"""L2 correctness: model zoo shapes, Pallas/ref path equivalence, the
hybrid latency decode, and the analytic compute-intensity accounting."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


def _x(b=4, seq=32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, seq, M.NUM_FEATURES)).astype(np.float32))


@pytest.mark.parametrize("name", M.MODELS)
def test_output_shape(name):
    p = M.init_params(name, 32)
    out = M.apply(name, p, _x())
    assert out.shape == (4, M.HEAD_OUT)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("name", M.MODELS)
def test_pallas_path_matches_ref_path(name):
    p = M.init_params(name, 32)
    x = _x(seed=42)
    a = M.apply(name, p, x, use_pallas=False)
    b = M.apply(name, p, x, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seq", [16, 32, 64])
def test_seq_lengths_supported(seq):
    for name in ("c3", "rb"):
        p = M.init_params(name, seq)
        out = M.apply(name, p, _x(seq=seq))
        assert out.shape == (4, M.HEAD_OUT)


def test_init_deterministic():
    a = M.init_params("c3", 32, seed=1)
    b = M.init_params("c3", 32, seed=1)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_decode_latency_class_vs_regression():
    # Construct outputs where head F picks class 5 and head E picks ">8"
    # with regression 100/LAT_SCALE; head S picks class 0.
    out = np.full((1, M.HEAD_OUT), -10.0, dtype=np.float32)
    out[0, 5] = 10.0  # F class 5
    out[0, 10] = 0.0  # F regression (ignored)
    base_e = M.NUM_CLASSES + 1
    out[0, base_e + 9] = 10.0  # E class ">8"
    out[0, base_e + 10] = 100.0 / M.LAT_SCALE
    base_s = 2 * (M.NUM_CLASSES + 1)
    out[0, base_s + 0] = 10.0  # S class 0
    lat = np.asarray(M.decode_latency(jnp.asarray(out)))
    assert lat[0, 0] == 5.0
    assert abs(lat[0, 1] - 100.0) < 1e-4
    assert lat[0, 2] == 0.0


def test_decode_latency_regression_floor():
    # ">8" class with a tiny regression must still decode to >= 9 cycles
    # (the class already asserts the latency exceeds 8).
    out = np.full((1, M.HEAD_OUT), -10.0, dtype=np.float32)
    out[0, 9] = 10.0  # F ">8"
    out[0, 10] = 0.001
    lat = np.asarray(M.decode_latency(jnp.asarray(out)))
    assert lat[0, 0] >= 9.0


def test_flops_ordering_matches_paper():
    """Table 4: FC < CNN ordering of intensity, LSTM/TX well above CNNs."""
    seq = 32
    f = {m: M.flops(m, seq) for m in M.MODELS}
    assert f["c1"] < f["c3"] <= f["rb"]
    assert f["c3"] < f["lstm2"]
    assert f["c3"] < f["tx2"]


def test_param_specs_order_is_stable():
    names1 = [n for n, _ in M.param_specs("rb", 32)]
    names2 = [n for n, _ in M.param_specs("rb", 32)]
    assert names1 == names2
    assert names1[0] == "conv0/w" and names1[-1] == "out/b"


def test_batch_consistency():
    """Per-sample outputs must not depend on batch composition."""
    p = M.init_params("c3", 32)
    x = _x(b=8, seed=9)
    full = np.asarray(M.apply("c3", p, x))
    single = np.asarray(M.apply("c3", p, x[2:3]))
    np.testing.assert_allclose(full[2:3], single, rtol=1e-5, atol=1e-5)
