"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal for the AOT path: the artifact the
rust runtime executes is lowered through these kernels, while training
runs through the refs — they must agree. Hypothesis sweeps shapes/dtypes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv1d, dense, ref

RTOL, ATOL = 1e-5, 1e-5


def _arr(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ----------------------------------------------------------------------
# conv1d_k2s2
# ----------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    half_l=st.integers(1, 16),
    c=st.integers(1, 64),
    c2=st.integers(1, 64),
    seed=st.integers(0, 2**16),
)
def test_conv_matches_ref_sweep(b, half_l, c, c2, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, 2 * half_l, c))
    w = _arr(rng, (2 * c, c2))
    bias = _arr(rng, (c2,))
    got = conv1d.conv1d_k2s2(x, w, bias)
    want = ref.conv1d_k2s2_ref(x, w, bias)
    assert got.shape == (b, half_l, c2)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block_b", [1, 2, 32, 100])
def test_conv_block_size_invariance(block_b):
    """Any batch tiling must produce identical results (padding is sliced
    away)."""
    rng = np.random.default_rng(7)
    x = _arr(rng, (13, 8, 50))
    w = _arr(rng, (100, 64))
    b = _arr(rng, (64,))
    got = conv1d.conv1d_k2s2(x, w, b, block_b=block_b)
    want = ref.conv1d_k2s2_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_conv_relu_clamps_negative():
    x = -jnp.ones((2, 4, 3))
    w = jnp.ones((6, 5))
    b = jnp.zeros((5,))
    out = conv1d.conv1d_k2s2(x, w, b)
    assert float(jnp.max(out)) == 0.0


def test_conv_rejects_odd_length():
    with pytest.raises(AssertionError):
        conv1d.conv1d_k2s2(jnp.zeros((1, 3, 4)), jnp.zeros((8, 2)), jnp.zeros((2,)))


def test_conv_vmem_budget_for_model_zoo_shapes():
    """Every conv geometry used by the zoo fits the 4 MiB VMEM target."""
    for (l, c, c2) in [(32, 50, 64), (16, 64, 96), (8, 96, 128), (64, 50, 64), (32, 64, 96), (16, 96, 128)]:
        assert conv1d.vmem_bytes(conv1d.BLOCK_B, l, c, c2) < 4 << 20


# ----------------------------------------------------------------------
# dense
# ----------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 70),
    d=st.integers(1, 128),
    h=st.integers(1, 64),
    relu=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref_sweep(b, d, h, relu, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, d))
    w = _arr(rng, (d, h))
    bias = _arr(rng, (h,))
    got = dense.dense(x, w, bias, relu=relu)
    want = ref.dense_ref(x, w, bias, relu=relu)
    assert got.shape == (b, h)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_dense_linear_preserves_sign():
    x = jnp.array([[-2.0, 1.0]])
    w = jnp.eye(2)
    b = jnp.zeros((2,))
    out = dense.dense(x, w, b, relu=False)
    np.testing.assert_allclose(out, x, rtol=0, atol=0)


# ----------------------------------------------------------------------
# residual block (ref only; exercised through the rb model)
# ----------------------------------------------------------------------


def test_residual_identity_at_zero_weights():
    rng = np.random.default_rng(3)
    x = jnp.abs(_arr(rng, (2, 4, 8)))  # positive so the final relu is identity
    z = jnp.zeros((8, 8))
    zb = jnp.zeros((8,))
    out = ref.residual_block_ref(x, z, zb, z, zb)
    np.testing.assert_allclose(out, x, rtol=0, atol=0)
