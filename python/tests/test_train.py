"""Training-loop tests on a small synthetic dataset.

The synthetic task plants a learnable signal (fetch latency depends on a
single input feature) so one epoch of Adam must reduce loss and produce a
usable .smw + meta artifact.
"""

import os
import struct

import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.dataset import Dataset
from compile.smw import read_smw


def make_smd(path, n=2000, seq=8, seed=0):
    """Write a synthetic .smd where labels are derivable from features."""
    rng = np.random.default_rng(seed)
    feats = rng.random((n, seq, M.NUM_FEATURES)).astype("<f4") * 0.5
    # Plant signal: fetch latency = round(4 * feature[0,28]); exec = fetch+1;
    # store = 0.
    f_lat = np.round(feats[:, 0, 28] * 8).astype("<f4")
    labels = np.stack([f_lat, f_lat + 1, np.zeros(n, "<f4")], axis=1)
    with open(path, "wb") as f:
        f.write(b"SMD1")
        f.write(struct.pack("<II", seq, M.NUM_FEATURES))
        f.write(struct.pack("<Q", n))
        rows = np.concatenate([feats.reshape(n, -1), labels], axis=1).astype("<f4")
        f.write(rows.tobytes())
    return path


@pytest.fixture(scope="module")
def smd(tmp_path_factory):
    d = tmp_path_factory.mktemp("train")
    return make_smd(str(d / "toy.smd"))


def test_dataset_reader_shapes(smd):
    ds = Dataset(smd)
    assert ds.seq_len == 8 and ds.nfeat == M.NUM_FEATURES
    x, y = ds.batch("train", 0, 32)
    assert x.shape == (32, 8, M.NUM_FEATURES)
    assert y.shape == (32, 3)
    # Splits are disjoint and cover the dataset.
    total = sum(ds.split_size(s) for s in ("train", "val", "test"))
    assert total == ds.n


def test_training_reduces_loss_and_writes_artifacts(smd, tmp_path):
    out = str(tmp_path)
    params, errs, history = T.train(
        smd, "fc2", out, epochs=6, batch_size=64, lr=3e-3, quiet=True
    )
    assert history[-1] < history[0] * 0.9, f"val loss did not drop: {history}"
    # Planted signal is learnable: fetch error far below the 1.0 of noise.
    assert errs[0] < 0.5, f"fetch err {errs[0]}"
    tensors = read_smw(os.path.join(out, "fc2.smw"))
    names = [n for n, _ in tensors]
    assert names == [n for n, _ in M.param_specs("fc2", 8)]
    meta = open(os.path.join(out, "fc2.meta")).read()
    assert "mode hyb" in meta and "seq_len 8" in meta


def test_regression_mode_trains(smd, tmp_path):
    _, errs, history = T.train(
        smd, "fc2", str(tmp_path), epochs=2, batch_size=64, mode="reg", quiet=True
    )
    assert history[-1] < history[0]
    meta = open(os.path.join(str(tmp_path), "fc2.meta")).read()
    assert "mode reg" in meta


def test_hybrid_beats_regression_on_small_latencies(smd, tmp_path):
    """Paper §2.3: classification distinguishes small latencies better."""
    _, errs_h, _ = T.train(smd, "fc2", None, epochs=4, batch_size=64, lr=3e-3, quiet=True)
    _, errs_r, _ = T.train(
        smd, "fc2", None, epochs=4, batch_size=64, lr=3e-3, mode="reg", quiet=True
    )
    # Fetch latencies in the toy set are 0..8 — exactly the hybrid sweet
    # spot. Allow equality slack but hybrid must not be meaningfully worse.
    assert errs_h[0] <= errs_r[0] * 1.25, f"hyb {errs_h[0]} vs reg {errs_r[0]}"


def test_prediction_error_metric():
    """E = |pred - y| / (y + 1), the paper's §2.5 definition."""
    import jax.numpy as jnp

    out = np.zeros((2, M.HEAD_OUT), dtype=np.float32)
    # Sample 0: predict class 2 for all three heads.
    for t in range(3):
        out[:, t * (M.NUM_CLASSES + 1) + 2] = 10.0
    labels = jnp.asarray(np.array([[2.0, 4.0, 0.0], [2.0, 2.0, 2.0]], np.float32))
    errs = np.asarray(T.prediction_error(jnp.asarray(out), labels))
    np.testing.assert_allclose(errs[0], 0.0, atol=1e-6)  # fetch exact
    np.testing.assert_allclose(errs[1], (2.0 / 5.0) / 2, atol=1e-6)
