"""Collection guard: skip the suite gracefully when heavy deps are absent.

Every test module imports JAX (directly or through ``compile.*``), and
``test_kernel.py`` additionally needs hypothesis. On environments without
them (e.g. the rust-only CI leg) collecting the modules would error out,
so we ignore them instead — pytest then exits with "no tests collected",
which CI treats as success.
"""

import importlib.util

collect_ignore_glob = []
if importlib.util.find_spec("jax") is None:
    collect_ignore_glob = ["test_*.py"]
elif importlib.util.find_spec("hypothesis") is None:
    collect_ignore_glob = ["test_kernel.py"]
