"""AOT export tests: HLO text artifacts + manifests + weight containers."""

import os

import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.smw import read_smw, write_smw


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("aot"))
    aot.export_model("c3", 16, out, batches=(1, 4), quiet=True)
    return out


def test_hlo_text_artifacts_exist(exported):
    for b in (1, 4):
        path = os.path.join(exported, f"c3_b{b}.hlo.txt")
        assert os.path.exists(path)
        text = open(path).read()
        assert "ENTRY" in text, "not HLO text"
        assert "f32[" in text


def test_export_manifest(exported):
    manifest = open(os.path.join(exported, "c3.export")).read()
    assert "model c3" in manifest
    assert "seq_len 16" in manifest
    assert "batches 1 4" in manifest
    names = [line for line in manifest.splitlines() if line.startswith("weights")][0]
    assert "conv0/w" in names and "out/b" in names


def test_init_weights_match_specs(exported):
    tensors = read_smw(os.path.join(exported, "c3.init.smw"))
    specs = M.param_specs("c3", 16)
    assert [n for n, _ in tensors] == [n for n, _ in specs]
    for (_, arr), (_, shape) in zip(tensors, specs):
        assert arr.shape == shape


def test_smw_roundtrip(tmp_path):
    tensors = [
        ("a/w", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b", np.array([1.5, -2.5], dtype=np.float32)),
    ]
    p = str(tmp_path / "t.smw")
    write_smw(p, tensors)
    back = read_smw(p)
    assert [n for n, _ in back] == ["a/w", "b"]
    np.testing.assert_array_equal(back[0][1], tensors[0][1])
    np.testing.assert_array_equal(back[1][1], tensors[1][1])


def test_batch_padding_future_proof():
    """Export rejects nothing at small seq; kernel padding handles any
    batch that is not a multiple of the pallas block."""
    x = np.random.default_rng(0).normal(size=(3, 16, M.NUM_FEATURES)).astype(np.float32)
    import jax.numpy as jnp

    p = M.init_params("c3", 16)
    out = M.apply("c3", p, jnp.asarray(x), use_pallas=True)
    assert out.shape == (3, M.HEAD_OUT)
